"""Deterministic fault injection for TRA execution (the fault model).

Parallel environments fail; the paper's claim that TRA programs are
"easily executed with high efficiency in a parallel or distributed
environment" is only credible if the *recovery* paths are testable.  This
module provides the harness: a :class:`FaultInjector` that
:class:`~repro.core.engine.Engine` threads through every executor so
simulated failures fire at deterministic, plan-addressable points:

* **site failures** (:class:`SimulatedFailure`) — a node/host dies.  Fire
  per *run* (``step`` selector: the N-th ``CompiledExpr.run`` of the
  engine's artifacts — how a mid-training kill is simulated) or per *plan
  node* (``node`` selector, see below).
* **device OOM** (:class:`DeviceOOM`) — the fused Σ∘⋈ contraction path
  exhausts device memory.  The spec succeeds only once the engine has
  degraded to the chunked streaming fallback with a small enough chunk
  (``ok_chunk``), which is exactly what the engine's halving backoff
  ladder does (``Engine(degrade=True)``).
* **compile failures** (:class:`CompileFailure`) — a distributed executor
  cannot build its artifact; exercises the ``shard_map/gspmd → jit →
  reference`` fallback ladder.
* **stragglers** — a plan node (or whole run) is delayed by ``delay``
  seconds; lets timeout/monitoring machinery be tested without real slow
  hosts.
* **numeric faults** — a plan node's output is poisoned with NaN, so the
  ``check_numerics`` provenance machinery (:mod:`repro.core.guards`) can
  be shown to attribute the *first* non-finite value to the exact node.

**Node addressing.**  Node-scoped faults are keyed on *plan-signature
node ids*: the postorder index a node gets in
:func:`repro.core.engine.plan_sig` (shared subexpressions appear once).
``node`` may be that integer id or a substring of the node's label
(``"7:FusedJoinAgg[matMul→matAdd]"``); labels for a compiled artifact
come from :func:`repro.core.guards.label_nodes`.

**Timing caveat (documented, load-bearing).**  On the eager ``reference``
executor node hooks fire on *every run*, so ``step``-scoped node faults
behave per-step.  On the staged executors (``jit``/``gspmd``/
``shard_map``) node hooks fire at *trace* time — once per compile — so a
node-scoped fault there is baked into (or raised out of) the compile;
run-scoped faults (``step=`` with ``node=None``) fire on every executor
because they hook ``CompiledExpr.run`` itself.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple, Union


class FaultError(RuntimeError):
    """Base class of all injected faults."""


class SimulatedFailure(FaultError):
    """A simulated site/node failure (the checkpoint/restart trigger).

    Canonical definition — :mod:`repro.runtime.trainer` re-exports it, so
    the dense trainer and the TRA trainer recover from the same fault
    type.
    """


class DeviceOOM(FaultError):
    """Simulated device out-of-memory in the fused contraction path."""


class CompileFailure(FaultError):
    """Simulated executor compile failure (degradation-ladder trigger)."""


#: The transient half of the taxonomy: failures a retry can clear because
#: they name a condition of the *attempt* (a site died, a device filled,
#: an executor's build flaked) rather than of the request.  Everything
#: else — type errors, bad payloads, shape mismatches — is permanent:
#: retrying replays the same deterministic rejection.
TRANSIENT_FAULTS = (SimulatedFailure, DeviceOOM, CompileFailure)


def is_transient(err: BaseException) -> bool:
    """Classify an execution failure against the fault taxonomy.

    True for the injected transient kinds (:data:`TRANSIENT_FAULTS`),
    for real XLA runtime failures (``XlaRuntimeError`` — device resets,
    allocation failures), and for numeric-guard trips
    (:class:`repro.core.guards.NumericsError`): recomputation is
    deterministic, so a *persistent* poisoning exhausts any retry budget
    while injected/transient corruption clears on the next attempt.
    """
    if isinstance(err, TRANSIENT_FAULTS):
        return True
    if any(t.__name__ == "XlaRuntimeError" for t in type(err).__mro__):
        return True
    try:
        from repro.core.guards import NumericsError
    except ImportError:      # pragma: no cover - guards is a sibling
        return False
    return isinstance(err, NumericsError)


@dataclasses.dataclass
class _Fault:
    kind: str                              # site | oom | compile | straggler | nan
    node: Union[int, str, None] = None     # plan-sig node id or label substring
    step: Optional[int] = None             # 0-based run index (on_run counter)
    every: Optional[int] = None            # periodic: fire when step % every == 0
    times: int = 1                         # remaining firings; -1 = unlimited
    delay: float = 0.0                     # straggler sleep seconds
    ok_chunk: int = 0                      # oom: succeed when streaming chunk <= this
    ok_bytes: Optional[int] = None         # oom: succeed when live bytes <= this
    executor: Optional[str] = None         # compile: executor that fails

    def matches_node(self, nid: int, label: str) -> bool:
        if isinstance(self.node, int):
            return self.node == nid
        if isinstance(self.node, str):
            return self.node in label
        return self.node is None

    def due_at(self, idx: int) -> bool:
        """Is this fault scheduled for run index ``idx``?

        ``step`` pins one run; ``every`` fires periodically (every N-th
        run, skipping run 0 so warm starts see at least one good tick).
        With neither selector a run-scoped fault never fires.
        """
        if self.step is not None:
            return self.step == idx
        if self.every is not None:
            return idx > 0 and idx % self.every == 0
        return False

    def spend(self) -> bool:
        """Consume one firing; False if the budget is exhausted."""
        if self.times == 0:
            return False
        if self.times > 0:
            self.times -= 1
        return True


class FaultInjector:
    """Scripted, deterministic fault source threaded through the Engine.

        inj = FaultInjector()
        inj.inject_site_failure(step=5)        # kill the 6th run
        eng = Engine(executor="jit", fault_injector=inj)

    Every fired fault is appended to ``self.log`` as a ``(kind, detail)``
    tuple so tests can assert exactly which recovery path executed.
    """

    def __init__(self) -> None:
        self._faults: List[_Fault] = []
        self.log: List[Tuple[str, str]] = []
        self.runs = 0                      # CompiledExpr.run invocations

    # -- scripting ---------------------------------------------------------
    def inject_site_failure(self, *, node=None, step: Optional[int] = None,
                            every: Optional[int] = None,
                            times: int = 1) -> "FaultInjector":
        """Kill one run (``step=``) or every N-th run (``every=``) — the
        periodic form is the chaos-harness schedule: a serving loop sees
        a site die on a fixed cadence and must keep its goodput SLO."""
        self._faults.append(_Fault("site", node=node, step=step,
                                   every=every, times=times))
        return self

    def inject_oom(self, *, node=None, ok_chunk: int = 1,
                   ok_bytes: Optional[int] = None,
                   times: int = -1) -> "FaultInjector":
        """OOM whenever the fused contraction runs unstreamed or with a
        streaming chunk larger than ``ok_chunk`` — models a fixed device
        memory budget, so the halving ladder deterministically bottoms
        out at the first rung that 'fits'.

        ``ok_bytes`` switches to the byte-accurate device model instead:
        the contraction fits iff its estimated live bytes (inputs +
        in-flight slices + output, as reported by the fused path) are
        under the budget.  This is the model the out-of-core tests use —
        an over-budget plan OOMs resident but fits once the host relation
        store streams its operands in key-range chunks."""
        self._faults.append(_Fault("oom", node=node, ok_chunk=ok_chunk,
                                   ok_bytes=ok_bytes, times=times))
        return self

    def inject_compile_failure(self, *, executor: str,
                               times: int = 1) -> "FaultInjector":
        self._faults.append(_Fault("compile", executor=executor,
                                   times=times))
        return self

    def inject_straggler(self, *, node=None, step: Optional[int] = None,
                         every: Optional[int] = None, delay: float = 0.05,
                         times: int = 1) -> "FaultInjector":
        self._faults.append(_Fault("straggler", node=node, step=step,
                                   every=every, delay=delay, times=times))
        return self

    def inject_nan(self, *, node, step: Optional[int] = None,
                   every: Optional[int] = None,
                   times: int = 1) -> "FaultInjector":
        """Poison a node's output with NaN — pinned to one run
        (``step=``), periodic (``every=``), or unconditional (neither).
        Periodic NaN only behaves per-run on the eager ``reference``
        executor (see the timing caveat in the module docstring)."""
        self._faults.append(_Fault("nan", node=node, step=step,
                                   every=every, times=times))
        return self

    # -- hooks (called by the Engine / executors) --------------------------
    def on_run(self) -> None:
        """Per ``CompiledExpr.run``; run-scoped site failures / stragglers."""
        idx = self.runs
        self.runs += 1
        for f in self._faults:
            if f.node is not None or not f.due_at(idx):
                continue
            if f.kind == "site" and f.spend():
                self.log.append(("site", f"run {idx}"))
                raise SimulatedFailure(f"injected site failure at run {idx}")
            if f.kind == "straggler" and f.spend():
                self.log.append(("straggler", f"run {idx} +{f.delay}s"))
                time.sleep(f.delay)

    def on_node(self, nid: int, label: str, data):
        """Per evaluated plan node.  May raise, sleep, or return a
        NaN-poisoned replacement for ``data`` (a jax array)."""
        out = data
        for f in self._faults:
            if f.node is None or not f.matches_node(nid, label):
                continue
            if (f.step is not None or f.every is not None) \
                    and not f.due_at(max(0, self.runs - 1)):
                continue
            if f.kind == "site" and f.spend():
                self.log.append(("site", label))
                raise SimulatedFailure(f"injected site failure at {label}")
            if f.kind == "straggler" and f.spend():
                self.log.append(("straggler", f"{label} +{f.delay}s"))
                time.sleep(f.delay)
            if f.kind == "nan" and f.spend():
                import jax.numpy as jnp
                self.log.append(("nan", label))
                if jnp.issubdtype(out.dtype, jnp.inexact):
                    out = out * jnp.asarray(float("nan"), out.dtype)
        return out

    def on_contraction(self, *, stream: bool, chunk: Optional[int],
                       nid: int = -1, label: str = "",
                       bytes_live: Optional[int] = None) -> None:
        """Inside the fused Σ∘⋈ path, before the contraction lowers."""
        for f in self._faults:
            if f.kind != "oom" or not f.matches_node(nid, label):
                continue
            if f.ok_bytes is not None:
                fits = bytes_live is not None and bytes_live <= f.ok_bytes
                limit = f"live bytes <= {f.ok_bytes}"
            else:
                fits = stream and chunk is not None and chunk <= f.ok_chunk
                limit = f"streaming chunk <= {f.ok_chunk}"
            if not fits and f.spend():
                mode = f"stream chunk={chunk}" if stream else "unstreamed"
                if bytes_live is not None:
                    mode += f" ~{bytes_live}B"
                self.log.append(("oom", f"{label or 'fused'} {mode}"))
                raise DeviceOOM(
                    f"injected device OOM in fused contraction ({mode}; "
                    f"fits only at {limit})")

    def on_compile(self, executor: str) -> None:
        """Before an executor builds its compiled artifact."""
        for f in self._faults:
            if f.kind == "compile" and f.executor == executor and f.spend():
                self.log.append(("compile", executor))
                raise CompileFailure(
                    f"injected compile failure on executor {executor!r}")
