"""Plan interpreters: logical TRA, local IA, and distributed GSPMD IA.

Three evaluation modes:

* ``_evaluate_tra``   — walk a logical plan with the dense eager ops.
* ``_evaluate_ia``    — walk a physical plan ignoring sites (semantics
  check: a valid IA plan must equal its TRA source after projecting away
  sites).
* ``_evaluate_ia(spmd=True)`` — production path.  The same walk, but every
  ``BCAST``/``SHUF``/input placement becomes a sharding constraint inside a
  single ``jit``; XLA emits the collective schedule that the placements
  dictate (all-gather for BCAST, all-to-all for SHUF, reduce-scatter /
  all-reduce for the two-phase-aggregation placements).

A fourth mode — explicit shard_map collectives — lives in
:mod:`repro.core.shardmap_exec`.

The public names ``evaluate_tra`` / ``evaluate_ia`` / ``jit_ia_plan`` are
**deprecated shims** over the internals: the supported entry points are
``Engine.run`` / ``Engine.compile`` in :mod:`repro.core.engine`, which add
the optimizer, the compile cache, and a uniform executor selection on top
of these walks.  The shims warn with ``stacklevel`` pointing at the caller,
so the CI deprecation gate (``-W error::DeprecationWarning`` filtered to
``repro.*``) proves nothing inside the library still routes through them
while oracle tests may keep calling them directly.
"""
from __future__ import annotations

import warnings
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import tra
from repro.core.plan import (Bcast, FusedJoinAgg, IAConst, IAInput, IANode,
                             LocalAgg, LocalConcat, LocalFilter, LocalJoin,
                             LocalMap, LocalPad, LocalTile, Placement, Shuf,
                             TraAgg, TraConcat, TraConst, TraFilter, TraInput,
                             TraJoin, TraNode, TraPad, TraReKey, TraTile,
                             TraTransform, as_node, children, infer,
                             postorder)
from repro.core.tra import TensorRelation


def _const_rel(rtype, fill: float) -> TensorRelation:
    shape = tuple(rtype.key_shape) + tuple(rtype.bound)
    return TensorRelation(jnp.full(shape, fill, rtype.dtype), rtype)


def _warn_deprecated(old: str, new: str) -> None:
    warnings.warn(f"repro.core.{old} is deprecated; use {new} "
                  f"(see repro.core.engine.Engine)",
                  DeprecationWarning, stacklevel=3)


def _evaluate_tra(node: TraNode, env: Dict[str, TensorRelation],
                  _cache: Optional[dict] = None,
                  fuse: bool = True,
                  chunk=None,
                  budget: Optional[int] = None,
                  ctx=None) -> TensorRelation:
    """Walk a logical plan with the dense eager ops.

    With ``fuse=True`` (default) every ``TraAgg(TraJoin(...))`` pair whose
    kernels admit it executes through :func:`tra.fused_join_agg` — the
    Σ∘⋈ contraction — instead of materializing the join grid.  Joins with
    more than one consumer are exempt (they are computed once and cached).
    Pass ``fuse=False`` to force the unfused pair (the correctness oracle).
    ``chunk`` forwards to the fused path's streaming reduction (``None`` =
    bytes-based default).  ``ctx`` is the engine's
    :class:`~repro.core.guards.ExecContext`; when active, every computed
    node value passes through ``ctx.on_node`` (fault injection + per-node
    finite checks with plan provenance).
    """
    node = as_node(node)
    cache = _cache if _cache is not None else {}
    shared: set = set()
    if fuse:
        counts: Dict[int, int] = {}
        for n in postorder(node):
            for c in children(n):
                counts[id(c)] = counts.get(id(c), 0) + 1
        shared = {i for i, k in counts.items() if k > 1}

    def rec(n):
        if id(n) in cache:
            return cache[id(n)]
        if isinstance(n, TraInput):
            out = env[n.name]
        elif isinstance(n, TraConst):
            out = _const_rel(n.rtype, n.fill)
        elif isinstance(n, TraPad):
            out = tra.pad(rec(n.child), n.key_shape)
        elif isinstance(n, TraJoin):
            out = tra.join(rec(n.left), rec(n.right),
                           n.join_keys_l, n.join_keys_r, n.kernel)
        elif isinstance(n, TraAgg):
            c = n.child
            if fuse and isinstance(c, TraJoin) and id(c) not in cache \
                    and id(c) not in shared \
                    and tra.can_fuse(c.kernel, n.kernel):
                out = tra.fused_join_agg(
                    rec(c.left), rec(c.right), c.join_keys_l,
                    c.join_keys_r, c.kernel, n.group_by, n.kernel,
                    chunk=chunk, budget=budget, ctx=ctx, node=n)
            else:
                out = tra.agg(rec(n.child), n.group_by, n.kernel)
        elif isinstance(n, TraReKey):
            out = tra.rekey(rec(n.child), n.key_func)
        elif isinstance(n, TraFilter):
            out = tra.filt(rec(n.child), n.bool_func)
        elif isinstance(n, TraTransform):
            out = tra.transform(rec(n.child), n.kernel)
        elif isinstance(n, TraTile):
            out = tra.tile(rec(n.child), n.tile_dim, n.tile_size)
        elif isinstance(n, TraConcat):
            out = tra.concat(rec(n.child), n.key_dim, n.array_dim)
        else:
            raise TypeError(type(n))
        if ctx is not None and ctx.active:
            out = ctx.on_node(n, out)
        cache[id(n)] = out
        return out

    return rec(node)


def evaluate_tra(node: TraNode, env: Dict[str, TensorRelation],
                 _cache: Optional[dict] = None,
                 fuse: bool = True) -> TensorRelation:
    """Deprecated shim — use ``Engine(executor="reference").run(expr, ...)``."""
    _warn_deprecated("evaluate_tra", 'Engine(executor="reference").run')
    return _evaluate_tra(node, env, _cache, fuse)


def _pspec_for(placement: Optional[Placement], rtype) -> P:
    """PartitionSpec over the dense layout ``key_shape + bound``."""
    if placement is None or placement.is_replicated:
        return P()
    entries = []
    for d in range(rtype.key_arity):
        ax = placement.axis_of_dim(d)
        entries.append(ax)
    entries += [None] * rtype.rank
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _evaluate_ia(node: IANode, env: Dict[str, TensorRelation],
                 mesh: Optional[Mesh] = None,
                 spmd: bool = False,
                 _cache: Optional[dict] = None,
                 chunk=None,
                 budget: Optional[int] = None,
                 ctx=None) -> TensorRelation:
    """Evaluate a physical plan.

    With ``spmd=True`` (requires ``mesh``) every placement-bearing node gets
    a ``with_sharding_constraint`` so that, lowered under ``jit``, XLA
    produces exactly the data movement the IA plan prescribes.
    """
    node = as_node(node)
    cache = _cache if _cache is not None else {}
    if id(node) in cache:
        return cache[id(node)]

    def rec(n):
        return _evaluate_ia(n, env, mesh, spmd, cache, chunk, budget, ctx)

    def constrain(rel: TensorRelation, placement: Placement) -> TensorRelation:
        if not spmd or mesh is None or placement is None:
            return rel
        if placement.has_duplicates:
            # partial duplicates are a transient SPMD state; the pending
            # reduction materializes at the next SHUF/BCAST constraint
            return rel
        spec = _pspec_for(placement, rel.rtype)
        data = jax.lax.with_sharding_constraint(
            rel.data, NamedSharding(mesh, spec))
        return TensorRelation(data, rel.rtype, rel.mask)

    if isinstance(node, IAInput):
        out = constrain(env[node.name], node.placement)
    elif isinstance(node, IAConst):
        out = constrain(_const_rel(node.rtype, node.fill), node.placement)
    elif isinstance(node, LocalPad):
        out = tra.pad(rec(node.child), node.key_shape)
        out = constrain(out, infer(node).placement)
    elif isinstance(node, Bcast):
        out = constrain(rec(node.child), Placement.replicated())
    elif isinstance(node, Shuf):
        out = constrain(rec(node.child),
                        Placement.partitioned(node.part_dims, node.axes))
    elif isinstance(node, LocalJoin):
        out = tra.join(rec(node.left), rec(node.right),
                       node.join_keys_l, node.join_keys_r, node.kernel)
        ti = infer(node)
        out = constrain(out, ti.placement)
    elif isinstance(node, LocalAgg):
        out = tra.agg(rec(node.child), node.group_by, node.kernel)
        ti = infer(node)
        out = constrain(out, ti.placement)
    elif isinstance(node, FusedJoinAgg):
        out = tra.fused_join_agg(rec(node.left), rec(node.right),
                                 node.join_keys_l, node.join_keys_r,
                                 node.join_kernel, node.group_by,
                                 node.agg_kernel, chunk=chunk,
                                 budget=budget, ctx=ctx, node=node)
        ti = infer(node)
        out = constrain(out, ti.placement)
    elif isinstance(node, LocalFilter):
        out = tra.filt(rec(node.child), node.bool_func)
    elif isinstance(node, LocalMap):
        child = rec(node.child)
        if node.kernel.name != "idOp":
            child = tra.transform(child, node.kernel)
        if node.key_func is not None:
            child = tra.rekey(child, node.key_func)
        out = child
    elif isinstance(node, LocalTile):
        out = tra.tile(rec(node.child), node.tile_dim, node.tile_size)
    elif isinstance(node, LocalConcat):
        out = tra.concat(rec(node.child), node.key_dim, node.array_dim)
    else:
        raise TypeError(type(node))
    if ctx is not None and ctx.active:
        out = ctx.on_node(node, out)
    cache[id(node)] = out
    return out


def evaluate_ia(node: IANode, env: Dict[str, TensorRelation],
                mesh: Optional[Mesh] = None,
                spmd: bool = False,
                _cache: Optional[dict] = None) -> TensorRelation:
    """Deprecated shim — use ``Engine.run`` (``executor="reference"`` for
    the sites-ignoring walk, ``executor="gspmd"`` for the SPMD path)."""
    _warn_deprecated("evaluate_ia", "Engine.run")
    return _evaluate_ia(node, env, mesh, spmd, _cache)


def _jit_ia_plan(root: IANode, mesh: Mesh,
                 input_order: Optional[list] = None
                 ) -> Tuple[Callable, list]:
    """Build a jitted function ``(*arrays) -> array`` executing ``root``.

    Input arrays arrive in ``input_order`` (names); shardings follow the
    plan's input placements.  The returned callable is suitable for
    ``.lower().compile()`` dry-runs and for real execution.
    """
    root = as_node(root)
    inputs = [n for n in postorder(root) if isinstance(n, IAInput)]
    by_name = {n.name: n for n in inputs}
    names = input_order or sorted(by_name)

    def fn(*arrays):
        env = {}
        for name, arr in zip(names, arrays):
            node = by_name[name]
            env[name] = TensorRelation(arr, node.rtype)
        rel = _evaluate_ia(root, env, mesh=mesh, spmd=True)
        return rel.data

    in_shardings = tuple(
        NamedSharding(mesh, _pspec_for(by_name[n].placement, by_name[n].rtype))
        for n in names)
    return jax.jit(fn, in_shardings=in_shardings), names


def jit_ia_plan(root: IANode, mesh: Mesh,
                input_order: Optional[list] = None
                ) -> Tuple[Callable, list]:
    """Deprecated shim — use ``Engine(mesh, executor="gspmd").compile``."""
    _warn_deprecated("jit_ia_plan", 'Engine(mesh, executor="gspmd").compile')
    return _jit_ia_plan(root, mesh, input_order)


def _merge_ia_inputs(roots) -> Dict[str, IAInput]:
    """name → IAInput over several physical roots; conflicting declarations
    (type or placement) for one name are rejected."""
    by_name: Dict[str, IAInput] = {}
    for root in roots:
        for n in postorder(as_node(root)):
            if isinstance(n, IAInput):
                prev = by_name.get(n.name)
                if prev is not None and (prev.rtype != n.rtype
                                         or prev.placement != n.placement):
                    raise ValueError(
                        f"input {n.name!r} declared with conflicting "
                        f"type/placement across roots: "
                        f"{prev.placement.describe()} vs "
                        f"{n.placement.describe()}")
                by_name[n.name] = n
    return by_name


def _jit_ia_plans(roots, mesh: Mesh,
                  chunk=None,
                  budget: Optional[int] = None,
                  ctx=None) -> Tuple[Callable, list]:
    """Multi-root variant of :func:`_jit_ia_plan`: one jitted function
    ``(*arrays) -> tuple(arrays)`` executing every physical root under the
    shared SPMD input environment (required by ``Engine.value_and_grad``
    tuples on the GSPMD executor)."""
    roots = tuple(as_node(r) for r in roots)
    by_name = _merge_ia_inputs(roots)
    names = sorted(by_name)

    def fn(*arrays):
        env = {}
        for name, arr in zip(names, arrays):
            env[name] = TensorRelation(arr, by_name[name].rtype)
        cache: dict = {}
        return tuple(
            _evaluate_ia(r, env, mesh=mesh, spmd=True, _cache=cache,
                         chunk=chunk, budget=budget, ctx=ctx).data
            for r in roots)

    in_shardings = tuple(
        NamedSharding(mesh, _pspec_for(by_name[n].placement, by_name[n].rtype))
        for n in names)
    return jax.jit(fn, in_shardings=in_shardings), names
