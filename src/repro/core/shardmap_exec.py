"""Explicit-collective IA executor (paper-faithful `shard_map` mode).

Where the GSPMD executor *describes* placements and lets XLA choose the
collectives, this executor *is* the IA: every ``BCAST`` is a
``jax.lax.all_gather``, every ``SHUF`` an ``all_to_all`` (or a local slice /
gather, depending on source and target placements), and the two-phase
aggregation state (``dup_axes``) resolves through ``psum_scatter``
(reduce-scatter) or ``psum`` (all-reduce) — exactly the collective schedule
the paper's cost model prices.

Supported subset (documented): continuous relations (no masks — push filters
to the logical layer first), local joins / aggregations / kernel maps /
tiles / concats.  Key-rewriting maps require a replicated input.  This mode
is the semantics reference for the distributed algebra and runs in tests on
host-device meshes; the production models use the GSPMD mode.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import tra
from repro.core.interp import _merge_ia_inputs, _pspec_for, _warn_deprecated
from repro.core.plan import (Bcast, FusedJoinAgg, IAConst, IAInput, IANode,
                             LocalAgg, LocalConcat, LocalFilter, LocalJoin,
                             LocalMap, LocalPad, LocalTile, Placement, Shuf,
                             TypeInfo, as_node, infer, postorder)
from repro.core.tra import RelType, TensorRelation

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                      # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map


def _local_rtype(info: TypeInfo, mesh: Mesh) -> RelType:
    ks = list(info.rtype.key_shape)
    p = info.placement
    if p is not None and p.kind == "partitioned":
        for d, ax in zip(p.dims, p.axes):
            size = mesh.shape[ax]
            if ks[d] % size:
                raise ValueError(
                    f"frontier dim {d} ({ks[d]}) not divisible by axis "
                    f"{ax} ({size})")
            ks[d] //= size
    return RelType(tuple(ks), info.rtype.bound, info.rtype.dtype)


def _cross_site_reduce(x: jax.Array, ax: str, kernel_name: Optional[str]
                       ) -> jax.Array:
    """All-reduce the pending partials along mesh axis ``ax`` with the agg
    kernel's combiner — the psum-equivalent for every associative reducer.

    ``matAdd`` is ``psum``, ``elemMax``/``elemMin`` are ``pmax``/``pmin``;
    any other associative kernel (``elemMul`` → product, ``minIndex``, …)
    gathers the per-site partials and folds them locally — same wire
    volume as the ring all-reduce's gather phase, and semantically exact
    because aggregation kernels are associative by construction.
    """
    if kernel_name in (None, "matAdd"):
        return jax.lax.psum(x, ax)
    if kernel_name == "elemMax":
        return jax.lax.pmax(x, ax)
    if kernel_name == "elemMin":
        return jax.lax.pmin(x, ax)
    from repro.core.kernels_registry import get_kernel
    kern = get_kernel(kernel_name)
    if not kern.is_associative:
        raise NotImplementedError(
            f"shard_map two-phase aggregation for kernel {kernel_name}")
    stacked = jax.lax.all_gather(x, ax, axis=0, tiled=False)
    if kern.reduce is not None:
        return kern.reduce(stacked, (0,))
    return tra._tree_fold(stacked, kern)


def _resolve_dups(x: jax.Array, src: Placement, tgt: Optional[Placement],
                  mesh: Mesh) -> Tuple[jax.Array, Placement]:
    """Reduce pending duplicate-key partials (R2-5's second phase).

    Additive reducers scatter straight through ``psum_scatter``
    (reduce-scatter); other associative reducers all-reduce via
    :func:`_cross_site_reduce` and, when the target placement partitions a
    dim along the dup axis, slice their local window afterwards — the same
    final placement at the cost of the all-reduce's extra gather.
    """
    if not src.dup_axes:
        return x, src
    remaining_dups = list(src.dup_axes)
    scattered = []            # (dim, axis) pairs landing partitioned
    if tgt is not None and tgt.kind == "partitioned":
        for d, ax in zip(tgt.dims, tgt.axes):
            if ax in remaining_dups:
                size = mesh.shape[ax]
                if x.shape[d] % size == 0:
                    if src.dup_kernel in (None, "matAdd"):
                        # reduce-scatter: sum over ax, scatter along d
                        x = jax.lax.psum_scatter(
                            x, ax, scatter_dimension=d, tiled=True)
                    else:
                        x = _cross_site_reduce(x, ax, src.dup_kernel)
                        local = x.shape[d] // size
                        idx = jax.lax.axis_index(ax)
                        x = jax.lax.dynamic_slice_in_dim(
                            x, idx * local, local, axis=d)
                    scattered.append((d, ax))
                else:
                    # fall back to all-reduce; the caller's _move slices
                    x = _cross_site_reduce(x, ax, src.dup_kernel)
                remaining_dups.remove(ax)
    for ax in remaining_dups:
        x = _cross_site_reduce(x, ax, src.dup_kernel)
    dims = list(src.dims) + [d for d, _ in scattered]
    axes = list(src.axes) + [ax for _, ax in scattered]
    return x, Placement.partitioned(dims, axes)


def _move(x: jax.Array, src: Placement, tgt: Placement,
          mesh: Mesh) -> jax.Array:
    """Repartition local block ``x`` from ``src`` to ``tgt`` placement."""
    x, src = _resolve_dups(x, src, tgt, mesh)
    src_map = {ax: d for d, ax in zip(src.dims, src.axes)}
    tgt_map = {} if tgt.kind == "replicated" \
        else {ax: d for d, ax in zip(tgt.dims, tgt.axes)}
    for ax in sorted(set(src_map) | set(tgt_map)):
        od, nd = src_map.get(ax), tgt_map.get(ax)
        if od == nd:
            continue
        if od is None:                         # replicated → sharded: slice
            size = mesh.shape[ax]
            local = x.shape[nd] // size
            idx = jax.lax.axis_index(ax)
            x = jax.lax.dynamic_slice_in_dim(x, idx * local, local, axis=nd)
        elif nd is None:                       # sharded → replicated: gather
            x = jax.lax.all_gather(x, ax, axis=od, tiled=True)
        else:                                  # dim change: all_to_all
            x = jax.lax.all_to_all(x, ax, split_axis=nd, concat_axis=od,
                                   tiled=True)
    return x


def _build_shardmap(roots, mesh: Mesh, chunk=None,
                    budget: Optional[int] = None, ctx=None):
    """Build the explicit-collective callable ONCE for a tuple of physical
    roots.

    Returns ``(call, names, out_infos)``: ``call(env) -> tuple`` of global
    :class:`TensorRelation` results.  Building at *compile* time (instead
    of per ``run``) lets :class:`~repro.core.engine.Engine`'s structural
    compile cache reuse the constructed ``shard_map`` across run calls —
    repeat executions of one plan signature are pure dispatch.  Multiple
    roots execute inside one ``shard_map`` under a shared input
    environment (the multi-output path ``Engine.value_and_grad`` needs).

    ``ctx`` threads the engine's fault injector into the local walk
    (node-scoped faults fire at trace time here — see
    :mod:`repro.core.faults`); per-node numerics stay off inside the
    collective program, the engine checks the outputs instead.
    """
    roots = tuple(as_node(r) for r in roots)
    cache: Dict[int, TypeInfo] = {}
    out_infos = tuple(infer(r, cache=cache) for r in roots)
    by_name = _merge_ia_inputs(roots)
    names = sorted(by_name)
    for r in roots:
        for n in postorder(r):
            if cache[id(n)].mask is not None:
                raise NotImplementedError(
                    "shard_map mode requires continuous relations")

    def local_fn(*arrs):
        local_env = dict(zip(names, arrs))
        memo: Dict[int, jax.Array] = {}

        def rec(node) -> jax.Array:
            if id(node) in memo:
                return memo[id(node)]
            info = cache[id(node)]
            if isinstance(node, IAInput):
                out = local_env[node.name]
            elif isinstance(node, IAConst):
                lt = _local_rtype(info, mesh)
                out = jnp.full(tuple(lt.key_shape) + tuple(lt.bound),
                               node.fill, lt.dtype)
            elif isinstance(node, LocalPad):
                ct = cache[id(node.child)]
                cx = rec(node.child)
                if tuple(node.key_shape) == ct.rtype.key_shape:
                    out = cx        # masks are rejected above → identity
                else:
                    # frontier growth: placement rules force a replicated
                    # child, so the local block IS the global relation
                    crel = TensorRelation(cx, RelType(
                        cx.shape[:ct.rtype.key_arity], ct.rtype.bound,
                        ct.rtype.dtype))
                    out = tra.pad(crel, node.key_shape).data
            elif isinstance(node, (Bcast, Shuf)):
                child = rec(node.child)
                src = cache[id(node.child)].placement
                tgt = info.placement
                out = _move(child, src, tgt, mesh)
            elif isinstance(node, LocalJoin):
                lt, rt = cache[id(node.left)], cache[id(node.right)]
                lx, rx = rec(node.left), rec(node.right)
                lx, rx = _align_join_windows(node, lt, rt, lx, rx, mesh)
                lrel = TensorRelation(lx, RelType(
                    lx.shape[:lt.rtype.key_arity], lt.rtype.bound,
                    lt.rtype.dtype))
                rrel = TensorRelation(rx, RelType(
                    rx.shape[:rt.rtype.key_arity], rt.rtype.bound,
                    rt.rtype.dtype))
                out = tra.join(lrel, rrel, node.join_keys_l,
                               node.join_keys_r, node.kernel).data
            elif isinstance(node, LocalAgg):
                ct = cache[id(node.child)]
                cx = rec(node.child)
                crel = TensorRelation(cx, RelType(
                    cx.shape[:ct.rtype.key_arity], ct.rtype.bound,
                    ct.rtype.dtype))
                out = tra.agg(crel, node.group_by, node.kernel).data
            elif isinstance(node, FusedJoinAgg):
                # Σᴸ∘⋈ᴸ in one step over the local key windows.  For the
                # partial (R2-5) phase the per-site result carries pending
                # duplicates that the next Shuf/Bcast resolves through
                # psum_scatter / psum exactly as for LocalAgg.
                lt, rt = cache[id(node.left)], cache[id(node.right)]
                lx, rx = rec(node.left), rec(node.right)
                lx, rx = _align_join_windows(node, lt, rt, lx, rx, mesh)
                lrel = TensorRelation(lx, RelType(
                    lx.shape[:lt.rtype.key_arity], lt.rtype.bound,
                    lt.rtype.dtype))
                rrel = TensorRelation(rx, RelType(
                    rx.shape[:rt.rtype.key_arity], rt.rtype.bound,
                    rt.rtype.dtype))
                out = tra.fused_join_agg(
                    lrel, rrel, node.join_keys_l, node.join_keys_r,
                    node.join_kernel, node.group_by, node.agg_kernel,
                    chunk=chunk, budget=budget, ctx=ctx, node=node).data
            elif isinstance(node, LocalMap):
                ct = cache[id(node.child)]
                cx = rec(node.child)
                perm = None
                if node.key_func is not None and \
                        not ct.placement.is_replicated:
                    from repro.core.plan import _detect_key_permutation
                    perm = _detect_key_permutation(node.key_func,
                                                   ct.rtype.key_shape)
                    if perm is None:
                        raise NotImplementedError(
                            "non-permutation key rewrite on partitioned "
                            "data in shard_map mode")
                crel = TensorRelation(cx, RelType(
                    cx.shape[:ct.rtype.key_arity], ct.rtype.bound,
                    ct.rtype.dtype))
                if node.kernel.name != "idOp":
                    crel = tra.transform(crel, node.kernel)
                if node.key_func is not None:
                    if perm is not None:
                        # pure key-axis permutation: local transpose
                        k = ct.rtype.key_arity
                        axes = list(perm) + list(range(k, crel.data.ndim))
                        crel = TensorRelation(
                            jnp.transpose(crel.data, axes),
                            RelType(tuple(crel.rtype.key_shape[p]
                                          for p in perm),
                                    crel.rtype.bound, crel.rtype.dtype))
                    else:
                        crel = tra.rekey(crel, node.key_func)
                out = crel.data
            elif isinstance(node, LocalTile):
                ct = cache[id(node.child)]
                cx = rec(node.child)
                crel = TensorRelation(cx, RelType(
                    cx.shape[:ct.rtype.key_arity], ct.rtype.bound,
                    ct.rtype.dtype))
                out = tra.tile(crel, node.tile_dim, node.tile_size).data
            elif isinstance(node, LocalConcat):
                ct = cache[id(node.child)]
                cx = rec(node.child)
                crel = TensorRelation(cx, RelType(
                    cx.shape[:ct.rtype.key_arity], ct.rtype.bound,
                    ct.rtype.dtype))
                out = tra.concat(crel, node.key_dim, node.array_dim).data
            elif isinstance(node, LocalFilter):
                raise NotImplementedError("filter in shard_map mode")
            else:
                raise TypeError(type(node))
            if ctx is not None and ctx.faults is not None:
                out = ctx.on_array(node, out)
            memo[id(node)] = out
            return out

        outs = []
        for root, oi in zip(roots, out_infos):
            res = rec(root)
            # resolve any trailing duplicate state so the output is clean
            p = oi.placement
            if p is not None and p.dup_axes:
                res, _ = _resolve_dups(res, p, None, mesh)
            outs.append(res)
        return tuple(outs)

    in_specs = tuple(_pspec_for(by_name[n].placement, by_name[n].rtype)
                     for n in names)
    out_specs = []
    for oi in out_infos:
        out_p = oi.placement
        if out_p is not None and out_p.dup_axes:
            out_p = Placement.partitioned(out_p.dims, out_p.axes)
        out_specs.append(_pspec_for(out_p, oi.rtype))
    # jit the whole shard_map so repeat runs of a cached artifact are a
    # single XLA dispatch — without it every call re-traces the explicit
    # collective program eagerly, which dwarfs the kernel time for
    # multi-root programs (the train-step loop runs one of these per
    # step).  Everything inside is static-shape jnp (masks are rejected
    # above), so jit is always legal here.
    fn = jax.jit(_shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                            out_specs=tuple(out_specs)))

    def call(env: Dict[str, TensorRelation]):
        arrays = [env[n].data for n in names]
        outs = fn(*arrays)
        return tuple(TensorRelation(o, oi.rtype)
                     for o, oi in zip(outs, out_infos))

    return call, names, out_infos


def _execute_shardmap(root: IANode, env: Dict[str, TensorRelation],
                      mesh: Mesh) -> TensorRelation:
    """One-shot single-root execution (builds the shard_map afresh — the
    Engine path builds once at compile time instead)."""
    call, _, _ = _build_shardmap((root,), mesh)
    return call(env)[0]


def execute_shardmap(root: IANode, env: Dict[str, TensorRelation],
                     mesh: Mesh) -> TensorRelation:
    """Deprecated shim — use ``Engine(mesh, executor="shard_map").run``."""
    _warn_deprecated("execute_shardmap",
                     'Engine(mesh, executor="shard_map").run')
    return _execute_shardmap(root, env, mesh)


def _align_join_windows(node, lt: TypeInfo, rt: TypeInfo,
                        lx: jax.Array, rx: jax.Array, mesh: Mesh):
    """Slice a replicated side down to the partitioned side's key window.

    Inside shard_map, a partitioned relation's local key indices are
    *local*; a replicated side still has global indices.  For every joined
    dim pair where exactly one side is sharded, the full side is sliced to
    the sharded side's window so local indices correspond.
    """
    lp, rp = lt.placement, rt.placement
    for dl, dr in zip(node.join_keys_l, node.join_keys_r):
        lax_name = None if lp is None or lp.kind != "partitioned" \
            else lp.axis_of_dim(dl)
        rax_name = None if rp is None or rp.kind != "partitioned" \
            else rp.axis_of_dim(dr)
        if lax_name is not None and rax_name is None:
            size = mesh.shape[lax_name]
            local = rx.shape[dr] // size
            idx = jax.lax.axis_index(lax_name)
            rx = jax.lax.dynamic_slice_in_dim(rx, idx * local, local,
                                              axis=dr)
        elif rax_name is not None and lax_name is None:
            size = mesh.shape[rax_name]
            local = lx.shape[dl] // size
            idx = jax.lax.axis_index(rax_name)
            lx = jax.lax.dynamic_slice_in_dim(lx, idx * local, local,
                                              axis=dl)
    return lx, rx
