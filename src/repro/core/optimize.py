"""Cost-based IA plan optimization (paper §4.2–4.3).

Two stages, mirroring the paper's two rule classes:

1. **Logical rewrites** (kernel-composition rules R1-*): filter merge &
   pushdown, transform fusion, transform∘join composition (R1-7),
   distributive transform past aggregation (R1-4).  These produce a small
   set of logical variants.

2. **Placement DP** (repartition rules R2-*): bottom-up dynamic programming
   over *interesting placements* (replicated; every single-dim partition;
   2-D partitions when the mesh offers two axes).  Join entries enumerate
   the R2-6 family — broadcast-left/right (BMM), co-partitioned shuffle
   (CPMM) and two-axis replication (RMM, the paper's §4.2.2 domain-specific
   rule, admitted by the per-axis local-join validity rule).  Aggregations
   enumerate direct (R2-4), shuffle-then-aggregate (Table 1) and two-phase
   partial aggregation (R2-5 — lowering to reduce-scatter / all-reduce).

Costs are the paper's exact float-movement metric via
:func:`repro.core.cost.comm_cost` — no estimation anywhere.

Beyond the paper, aggregation entries whose child is a join also enumerate
the fused Σ∘⋈ node (:class:`repro.core.plan.FusedJoinAgg`, direct and
two-phase).  Fusion never changes the float-movement metric, so selection
uses the cost model's ``tmp_floats`` (intermediate materialization) as a
tiebreak — fused plans win at equal comm cost.  :func:`fuse_join_agg`
applies the same collapse as a rewrite over existing physical plans.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import kernels_registry as kr
from repro.core.cost import cost_plan
from repro.core.plan import (Bcast, FusedJoinAgg, IAConst, IAInput, IANode,
                             LocalAgg, LocalConcat, LocalFilter, LocalJoin,
                             LocalMap, LocalPad, LocalTile, Placement, Shuf,
                             TraAgg, TraConcat, TraConst, TraFilter,
                             TraInput, TraJoin, TraNode, TraPad, TraReKey,
                             TraTile, TraTransform, TypeInfo, check_valid,
                             children, infer)
from repro.core.tra import can_fuse

PlacementSig = Tuple


def placement_sig(p: Optional[Placement]) -> PlacementSig:
    if p is None:
        return ("unknown",)
    return (p.kind, tuple(sorted(zip(p.dims, p.axes))), tuple(p.dup_axes))


# ==========================================================================
# Stage 1 — logical rewrites (R1 family)
# ==========================================================================

def logical_variants(node: TraNode, limit: int = 24) -> List[TraNode]:
    """Enumerate rewritten logical trees (original included, deduped)."""
    variants = [node]
    seen = {_tree_sig(node)}
    frontier = [node]
    while frontier and len(variants) < limit:
        cur = frontier.pop()
        for nxt in _rewrite_once(cur):
            sig = _tree_sig(nxt)
            if sig not in seen:
                seen.add(sig)
                variants.append(nxt)
                frontier.append(nxt)
    return variants


def _tree_sig(node: TraNode) -> Tuple:
    if isinstance(node, TraInput):
        return ("in", node.name)
    if isinstance(node, TraConst):
        return ("const", node.rtype.key_shape, node.rtype.bound, node.fill)
    if isinstance(node, TraPad):
        return ("pad", node.key_shape, _tree_sig(node.child))
    if isinstance(node, TraJoin):
        return ("join", node.join_keys_l, node.join_keys_r, node.kernel.name,
                _tree_sig(node.left), _tree_sig(node.right))
    if isinstance(node, TraAgg):
        return ("agg", node.group_by, node.kernel.name, _tree_sig(node.child))
    if isinstance(node, TraTransform):
        return ("map", node.kernel.name, _tree_sig(node.child))
    if isinstance(node, TraFilter):
        return ("filter", node.tag, _tree_sig(node.child))
    if isinstance(node, TraReKey):
        return ("rekey", node.tag, _tree_sig(node.child))
    if isinstance(node, TraTile):
        return ("tile", node.tile_dim, node.tile_size, _tree_sig(node.child))
    if isinstance(node, TraConcat):
        return ("concat", node.key_dim, node.array_dim, _tree_sig(node.child))
    raise TypeError(type(node))


def _rebuild(node: TraNode, new_children: Sequence[TraNode]) -> TraNode:
    if isinstance(node, TraJoin):
        return TraJoin(new_children[0], new_children[1], node.join_keys_l,
                       node.join_keys_r, node.kernel)
    if isinstance(node, TraAgg):
        return TraAgg(new_children[0], node.group_by, node.kernel)
    if isinstance(node, TraTransform):
        return TraTransform(new_children[0], node.kernel)
    if isinstance(node, TraFilter):
        return TraFilter(new_children[0], node.bool_func, node.tag)
    if isinstance(node, TraReKey):
        return TraReKey(new_children[0], node.key_func, node.tag)
    if isinstance(node, TraTile):
        return TraTile(new_children[0], node.tile_dim, node.tile_size)
    if isinstance(node, TraConcat):
        return TraConcat(new_children[0], node.key_dim, node.array_dim)
    if isinstance(node, TraPad):
        return TraPad(new_children[0], node.key_shape)
    return node


def _rewrite_once(node: TraNode) -> List[TraNode]:
    """All trees reachable by one rule application anywhere in ``node``."""
    out: List[TraNode] = []

    # rules at the root
    if isinstance(node, TraTransform):
        c = node.child
        # R1-2: fuse stacked transforms
        if isinstance(c, TraTransform):
            out.append(TraTransform(c.child,
                                    kr.compose(node.kernel, c.kernel)))
        # R1-7: compose transform into the join's projection kernel
        if isinstance(c, TraJoin):
            out.append(TraJoin(c.left, c.right, c.join_keys_l, c.join_keys_r,
                               kr.compose(node.kernel, c.kernel)))
        # R1-4: distributive transform commutes past aggregation
        if isinstance(c, TraAgg) and \
                c.kernel.name in node.kernel.distributes_over:
            out.append(TraAgg(TraTransform(c.child, node.kernel),
                              c.group_by, c.kernel))
    if isinstance(node, TraAgg):
        c = node.child
        # R1-4 reverse direction: pull a distributive transform back out
        if isinstance(c, TraTransform) and \
                node.kernel.name in c.kernel.distributes_over:
            out.append(TraTransform(TraAgg(c.child, node.group_by,
                                           node.kernel), c.kernel))
    if isinstance(node, TraFilter):
        c = node.child
        # R1-1: merge stacked filters
        if isinstance(c, TraFilter):
            f1, f2 = node.bool_func, c.bool_func
            out.append(TraFilter(c.child, lambda k: f1(k) and f2(k),
                                 tag=f"{node.tag}∧{c.tag}"))
        # R1-6: push a join-key-only filter into both join inputs
        if isinstance(c, TraJoin):
            pushed = _push_filter_through_join(node, c)
            if pushed is not None:
                out.append(pushed)

    # recurse into children
    if isinstance(node, TraJoin):
        for lv in _rewrite_once(node.left):
            out.append(_rebuild(node, (lv, node.right)))
        for rv in _rewrite_once(node.right):
            out.append(_rebuild(node, (node.left, rv)))
    elif not isinstance(node, (TraInput, TraConst)):
        for cv in _rewrite_once(node.child):
            out.append(_rebuild(node, (cv,)))
    return out


def _push_filter_through_join(f: TraFilter, j: TraJoin) -> Optional[TraNode]:
    """R1-6 — valid when the predicate only reads *joined* output dims.

    Joined output dims are exactly the ``join_keys_l`` positions (left and
    right agree there), so the predicate can be evaluated on either input.
    We verify the read-set empirically over the key grid: the predicate must
    be constant in every non-joined dim.
    """
    info = infer(j)
    import numpy as np
    k = info.rtype.key_arity
    jset = set(j.join_keys_l)
    grid = np.indices(info.rtype.key_shape).reshape(k, -1).T
    vals = np.asarray([bool(f.bool_func(tuple(int(x) for x in kk)))
                       for kk in grid]).reshape(info.rtype.key_shape)
    # constant along all non-join dims?
    for d in range(k):
        if d in jset:
            continue
        if not np.all(vals == np.take(vals, [0], axis=d)):
            return None

    def mk_pred(dim_map: Dict[int, int]) -> Callable:
        def pred(key: Tuple[int, ...]) -> bool:
            probe = [0] * k
            for out_d, in_d in dim_map.items():
                probe[out_d] = key[in_d]
            return bool(f.bool_func(tuple(probe)))
        return pred

    lmap = {jl: jl for jl in j.join_keys_l}           # out dim -> left dim
    rmap = {jl: jr for jl, jr in zip(j.join_keys_l, j.join_keys_r)}
    fl = TraFilter(j.left, mk_pred(lmap), tag=f"{f.tag}↓L")
    fr = TraFilter(j.right, mk_pred(rmap), tag=f"{f.tag}↓R")
    return TraJoin(fl, fr, j.join_keys_l, j.join_keys_r, j.kernel)


# ==========================================================================
# Stage 2 — placement DP (R2 family + domain-specific join placements)
# ==========================================================================

@dataclasses.dataclass
class PlanEntry:
    cost: int
    plan: IANode
    placement: Optional[Placement]
    # intermediate-materialization floats: a *tiebreak* under equal comm
    # cost, so fused Σ∘⋈ plans beat grid-materializing ones without ever
    # perturbing the paper's float-movement metric.
    tmp: int = 0


def interesting_placements(key_arity: int,
                           site_axes: Tuple[str, ...]) -> List[Placement]:
    out = [Placement.replicated()]
    for d in range(key_arity):
        for ax in site_axes:
            out.append(Placement.partitioned((d,), (ax,)))
    if len(site_axes) >= 2:
        for d0, d1 in itertools.permutations(range(key_arity), 2):
            out.append(Placement.partitioned((d0, d1), site_axes[:2]))
    return out


class Optimizer:
    def __init__(self, site_axes: Tuple[str, ...],
                 axis_sizes: Dict[str, int], accounting: str = "wire"):
        self.site_axes = tuple(site_axes)
        self.axis_sizes = dict(axis_sizes)
        self.accounting = accounting

    # -- helpers ----------------------------------------------------------
    def _entry(self, plan: IANode) -> Optional[PlanEntry]:
        from repro.core.plan import postorder as _post
        try:
            cache: Dict[int, TypeInfo] = {}
            info = infer(plan, cache=cache)
            for n in _post(plan):
                ti = cache[id(n)]
                # every local op must satisfy its placement preconditions
                # NOW — a later SHUF cannot repair locally-wrong results
                if isinstance(n, (LocalJoin, LocalAgg, LocalConcat,
                                  FusedJoinAgg, LocalPad)) \
                        and ti.placement is None:
                    return None
                # partitioned frontier dims must divide their axis sizes
                # (keeps both executors well-formed; GSPMD could pad, the
                # explicit shard_map mode cannot)
                p = ti.placement
                if p is not None and p.kind == "partitioned":
                    for d, ax in zip(p.dims, p.axes):
                        if ti.rtype.key_shape[d] % self.axis_sizes[ax]:
                            return None
        except (ValueError, TypeError):
            return None
        rep = cost_plan(plan, self.axis_sizes, self.accounting)
        return PlanEntry(rep.comm_floats, plan, info.placement,
                         rep.tmp_floats)

    def _add(self, table: Dict[PlacementSig, PlanEntry],
             entry: Optional[PlanEntry]) -> None:
        if entry is None:
            return
        sig = placement_sig(entry.placement)
        cur = table.get(sig)
        if cur is None or (entry.cost, entry.tmp) < (cur.cost, cur.tmp):
            table[sig] = entry

    def _closure(self, table: Dict[PlacementSig, PlanEntry],
                 key_arity: int) -> None:
        """Extend a table with BCAST/SHUF-moved versions of each entry."""
        base = list(table.values())
        for e in base:
            self._add(table, self._entry(Bcast(e.plan)))
            for p in interesting_placements(key_arity, self.site_axes):
                if p.is_replicated:
                    continue
                self._add(table,
                          self._entry(Shuf(e.plan, p.dims, p.axes)))

    # -- DP ----------------------------------------------------------------
    def tables(self, node: TraNode,
               input_placements: Dict[str, Placement],
               memo: Dict[int, Dict[PlacementSig, PlanEntry]]
               ) -> Dict[PlacementSig, PlanEntry]:
        if id(node) in memo:
            return memo[id(node)]
        table: Dict[PlacementSig, PlanEntry] = {}
        info = infer(node)

        if isinstance(node, TraInput):
            p = input_placements.get(node.name, Placement.replicated())
            self._add(table, self._entry(IAInput(node.name, node.rtype, p)))

        elif isinstance(node, TraConst):
            # a constant materializes locally at ANY placement for free —
            # seed the table with every interesting placement directly
            for p in interesting_placements(node.rtype.key_arity,
                                            self.site_axes):
                self._add(table, self._entry(
                    IAConst(node.rtype, node.fill, p)))

        elif isinstance(node, TraPad):
            ct = self.tables(node.child, input_placements, memo)
            for ce in ct.values():
                self._add(table, self._entry(
                    LocalPad(ce.plan, tuple(node.key_shape))))
                # frontier growth needs a replicated child
                self._add(table, self._entry(
                    LocalPad(Bcast(ce.plan), tuple(node.key_shape))))

        elif isinstance(node, TraJoin):
            lt = self.tables(node.left, input_placements, memo)
            rt_ = self.tables(node.right, input_placements, memo)
            for le in lt.values():
                for re_ in rt_.values():
                    self._add(table, self._entry(
                        LocalJoin(le.plan, re_.plan, node.join_keys_l,
                                  node.join_keys_r, node.kernel)))

        elif isinstance(node, TraAgg):
            ct = self.tables(node.child, input_placements, memo)
            for ce in ct.values():
                # R2-4: aggregate in place when already valid
                self._add(table, self._entry(
                    LocalAgg(ce.plan, node.group_by, node.kernel)))
                # Table 1 default: shuffle on group-by dims then aggregate
                dims = tuple(node.group_by)[:len(self.site_axes)]
                axes = self.site_axes[:len(dims)]
                self._add(table, self._entry(LocalAgg(
                    Shuf(ce.plan, dims, axes), node.group_by, node.kernel)))
                # R2-5: two-phase — partial agg, then reduce-scatter (SHUF)
                # or all-reduce (BCAST)
                if node.kernel.is_associative:
                    partial = LocalAgg(ce.plan, node.group_by, node.kernel,
                                       partial=True)
                    out_arity = len(node.group_by)
                    for p in interesting_placements(out_arity,
                                                    self.site_axes):
                        if p.is_replicated:
                            self._add(table, self._entry(Bcast(partial)))
                        else:
                            self._add(table, self._entry(
                                Shuf(partial, p.dims, p.axes)))
            # Σ∘⋈ contraction: when the agg consumes a join directly, also
            # enumerate the fused node over the join operands' tables —
            # same comm cost as the LocalAgg∘LocalJoin pair but no
            # materialized grid, so the tmp tiebreak selects it.
            if isinstance(node.child, TraJoin) \
                    and can_fuse(node.child.kernel, node.kernel):
                j = node.child
                lt = self.tables(j.left, input_placements, memo)
                rt_ = self.tables(j.right, input_placements, memo)
                out_arity = len(node.group_by)
                for le in lt.values():
                    for re_ in rt_.values():
                        self._add(table, self._entry(FusedJoinAgg(
                            le.plan, re_.plan, j.join_keys_l, j.join_keys_r,
                            j.kernel, node.group_by, node.kernel)))
                        partial = FusedJoinAgg(
                            le.plan, re_.plan, j.join_keys_l, j.join_keys_r,
                            j.kernel, node.group_by, node.kernel,
                            partial=True)
                        for p in interesting_placements(out_arity,
                                                        self.site_axes):
                            if p.is_replicated:
                                self._add(table,
                                          self._entry(Bcast(partial)))
                            else:
                                self._add(table, self._entry(
                                    Shuf(partial, p.dims, p.axes)))

        elif isinstance(node, TraTransform):
            ct = self.tables(node.child, input_placements, memo)
            for ce in ct.values():
                self._add(table, self._entry(
                    LocalMap(ce.plan, None, node.kernel)))

        elif isinstance(node, TraFilter):
            ct = self.tables(node.child, input_placements, memo)
            for ce in ct.values():
                self._add(table, self._entry(
                    LocalFilter(ce.plan, node.bool_func, tag=node.tag)))

        elif isinstance(node, TraReKey):
            ct = self.tables(node.child, input_placements, memo)
            for ce in ct.values():
                self._add(table, self._entry(
                    LocalMap(ce.plan, node.key_func, kr.get_kernel("idOp"),
                             tag=node.tag)))

        elif isinstance(node, TraTile):
            ct = self.tables(node.child, input_placements, memo)
            for ce in ct.values():
                self._add(table, self._entry(
                    LocalTile(ce.plan, node.tile_dim, node.tile_size)))

        elif isinstance(node, TraConcat):
            ct = self.tables(node.child, input_placements, memo)
            cinfo = infer(node.child)
            complement = tuple(d for d in range(cinfo.rtype.key_arity)
                               if d != node.key_dim)
            for ce in ct.values():
                self._add(table, self._entry(
                    LocalConcat(ce.plan, node.key_dim, node.array_dim)))
                dims = complement[:len(self.site_axes)]
                axes = self.site_axes[:len(dims)]
                self._add(table, self._entry(LocalConcat(
                    Shuf(ce.plan, dims, axes), node.key_dim,
                    node.array_dim)))
        else:
            raise TypeError(type(node))

        self._closure(table, info.rtype.key_arity)
        memo[id(node)] = table
        return table


@dataclasses.dataclass
class OptimizeResult:
    plan: IANode
    cost: int
    placement: Placement
    candidates: List[Tuple[str, int]]          # (description, cost) log
    logical_variants_tried: int


def optimize(root: TraNode,
             input_placements: Optional[Dict[str, Placement]] = None,
             site_axes: Tuple[str, ...] = ("sites",),
             axis_sizes: Optional[Dict[str, int]] = None,
             target: Optional[Placement] = None,
             try_logical_rewrites: bool = True,
             accounting: str = "wire") -> OptimizeResult:
    """Full optimization: logical variants × placement DP; min comm cost."""
    from repro.core.plan import as_node
    root = as_node(root)
    input_placements = input_placements or {}
    axis_sizes = axis_sizes or {a: 1 for a in site_axes}
    variants = logical_variants(root) if try_logical_rewrites else [root]

    best: Optional[PlanEntry] = None
    log: List[Tuple[str, int]] = []
    for var in variants:
        opt = Optimizer(site_axes, axis_sizes, accounting)
        table = opt.tables(var, input_placements, {})
        for sig, entry in table.items():
            if entry.placement is None or entry.placement.has_duplicates:
                continue
            if target is not None and placement_sig(entry.placement) \
                    != placement_sig(target):
                continue
            log.append((f"{sig}", entry.cost))
            if best is None or (entry.cost, entry.tmp) < (best.cost,
                                                          best.tmp):
                best = entry
    if best is None:
        raise ValueError("no valid physical plan found")
    check_valid(best.plan)
    log.sort(key=lambda x: x[1])
    return OptimizeResult(best.plan, best.cost, best.placement, log,
                          len(variants))


# ==========================================================================
# Physical-plan fusion rewrite (for hand-built / Table-1 default plans)
# ==========================================================================

def _rebuild_ia(node: IANode, kids: Sequence[IANode]) -> IANode:
    if isinstance(node, (IAInput, IAConst)):
        return node
    if isinstance(node, LocalPad):
        return LocalPad(kids[0], node.key_shape)
    if isinstance(node, LocalJoin):
        return LocalJoin(kids[0], kids[1], node.join_keys_l,
                         node.join_keys_r, node.kernel)
    if isinstance(node, FusedJoinAgg):
        return FusedJoinAgg(kids[0], kids[1], node.join_keys_l,
                            node.join_keys_r, node.join_kernel,
                            node.group_by, node.agg_kernel, node.partial)
    if isinstance(node, Bcast):
        return Bcast(kids[0])
    if isinstance(node, Shuf):
        return Shuf(kids[0], node.part_dims, node.axes)
    if isinstance(node, LocalAgg):
        return LocalAgg(kids[0], node.group_by, node.kernel, node.partial)
    if isinstance(node, LocalFilter):
        return LocalFilter(kids[0], node.bool_func, node.tag)
    if isinstance(node, LocalMap):
        return LocalMap(kids[0], node.key_func, node.kernel, node.tag)
    if isinstance(node, LocalTile):
        return LocalTile(kids[0], node.tile_dim, node.tile_size)
    if isinstance(node, LocalConcat):
        return LocalConcat(kids[0], node.key_dim, node.array_dim)
    raise TypeError(type(node))


def _valid_same_placement(cand: IANode, original: IANode) -> bool:
    """cand typechecks, every local op has a placement, and the subtree's
    final placement signature matches the original's (so parents above the
    rewrite site stay valid).

    Deliberately NOT plan.check_valid: that also rejects roots whose
    placement still carries pending duplicates, but a dup-carrying
    *subtree* (a partial FusedJoinAgg awaiting its Shuf/Bcast) is legal
    mid-plan — the signature comparison against the original covers it.
    """
    from repro.core.plan import postorder as _post
    try:
        cache: Dict[int, TypeInfo] = {}
        info = infer(cand, cache=cache)
        for n in _post(cand):
            if isinstance(n, (LocalJoin, LocalAgg, LocalConcat,
                              FusedJoinAgg, LocalPad)) \
                    and cache[id(n)].placement is None:
                return False
        orig = infer(original)
    except (ValueError, TypeError):
        return False
    return placement_sig(info.placement) == placement_sig(orig.placement)


def fuse_join_agg(root: IANode) -> IANode:
    """Collapse ``LocalAgg(Shuf?(LocalJoin(L, R)))`` into the fused Σ∘⋈
    node wherever the agg kernel is an associative reducer of the join
    kernel's output.

    With an interposed ``Shuf`` the rewrite produces the *two-phase* form
    ``Shuf(FusedJoinAgg(..., partial=True))`` — the shuffle of the small
    aggregated output (a reduce-scatter of the pending partials) replaces
    the shuffle of the whole join grid.  Candidates are only accepted when
    they typecheck and land on the same output placement as the original
    subtree, so the rewrite is always plan-validity-preserving.
    """
    cache: Dict[int, IANode] = {}

    def rec(n: IANode) -> IANode:
        if id(n) in cache:
            return cache[id(n)]
        kids = [rec(c) for c in children(n)]
        out = _rebuild_ia(n, kids)
        if isinstance(out, LocalAgg):
            c = out.child
            if isinstance(c, LocalJoin) and can_fuse(c.kernel, out.kernel):
                cand = FusedJoinAgg(c.left, c.right, c.join_keys_l,
                                    c.join_keys_r, c.kernel, out.group_by,
                                    out.kernel, partial=out.partial)
                if _valid_same_placement(cand, out):
                    out = cand
            elif (isinstance(c, Shuf) and isinstance(c.child, LocalJoin)
                    and not out.partial
                    and can_fuse(c.child.kernel, out.kernel)
                    and set(c.part_dims) <= set(out.group_by)):
                j = c.child
                odims = tuple(out.group_by.index(d) for d in c.part_dims)
                # partial=True leaves pending duplicates resolved by the
                # next Shuf/Bcast: psum/psum_scatter for matAdd, the
                # pmax/pmin/gather-fold psum-equivalents for every other
                # associative reducer (shardmap_exec._cross_site_reduce)
                for partial in (True, False):
                    fused = FusedJoinAgg(
                        j.left, j.right, j.join_keys_l, j.join_keys_r,
                        j.kernel, out.group_by, out.kernel, partial=partial)
                    cand = Shuf(fused, odims, c.axes)
                    if _valid_same_placement(cand, out):
                        out = cand
                        break
        cache[id(n)] = out
        return out

    return rec(root)
