"""Kernel-function registry for the TRA/IA.

The paper's TRA is a family of *higher-order* functions: every algebra op
takes a kernel function over plain arrays (in the paper: an MKL/CUDA kernel).
Here kernels are jnp callables obeying one convention:

    kernel.apply operates on the LAST ``rank`` dims of its operands and
    broadcasts over any leading (key/batch) dims.

That convention is what lets the dense executor evaluate a join by aligning
key dims and issuing a *single* batched kernel call (which XLA then maps onto
the MXU) instead of looping over tuples like the paper's Python engine.

Each kernel carries the metadata the optimizer needs:
  * ``out_bound``   — array-type inference (bound of the output),
  * ``flops``       — exact flop count for the compute roofline term,
  * ``is_associative``/``identity``/``reduce`` — for aggregation kernels,
  * ``distributes_over`` — names of agg kernels it distributes over (R1-4 /
    R1-7 side conditions),
  * ``vjp``         — the kernel-level derivative rule consumed by
    :mod:`repro.core.autodiff` (Tang et al., arXiv 2306.00088: backward
    passes are *derived* from the forward relational plan).  For a binary
    (join) kernel the rule is a pair of :class:`JoinVjp` specs — one per
    operand — each naming the *registered kernel* that computes that
    operand's cotangent from (cotangent, other operand); the autodiff
    transform then emits the cotangent as a TRA join+aggregation, so the
    backward graph is itself a ``TraNode`` DAG the optimizer can fuse.
    For a unary (transform) kernel the rule is a builder
    ``vjp(child_expr, out_expr, cot_expr) -> Expr`` written against the
    lazy frontend (again: plain TRA ops, never opaque jax autodiff).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Bound = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class JoinVjp:
    """Derivative rule for ONE operand of a binary (join) kernel.

    ``kernel`` names the registered kernel computing the operand's
    cotangent (or is the :class:`Kernel` itself, for parameterized
    factory kernels such as the einsum-frontend contractions);
    ``cot_first`` says whether the incoming cotangent is that kernel's
    first operand (the other forward operand is the remaining one).
    E.g. for ``matMul``: dL = g @ Rᵀ = ``matTranMulR(g, R)`` →
    ``JoinVjp("matTranMulR", cot_first=True)``.
    """

    kernel: Any                       # str (registered name) or Kernel
    cot_first: bool = True


@dataclasses.dataclass(frozen=True)
class Kernel:
    """A named array kernel usable inside TRA/IA operations."""

    name: str
    arity: int                                  # 1 or 2 operand arrays
    apply: Callable[..., jax.Array]
    out_bound: Callable[..., Bound]             # (*bounds) -> bound
    flops: Callable[..., int]                   # (*bounds) -> flop count
    is_associative: bool = False                # usable as an agg kernel
    identity: Optional[float] = None            # identity element for agg
    reduce: Optional[Callable[[jax.Array, Tuple[int, ...]], jax.Array]] = None
    distributes_over: Tuple[str, ...] = ()      # agg kernels f with k(f(a,b)) = f(k(a),k(b))
    # derivative rule (see module docstring): for arity 2 a pair of
    # Optional[JoinVjp] (None = that operand is non-differentiable); for
    # arity 1 a builder (child_expr, out_expr, cot_expr) -> Expr.
    vjp: Optional[Any] = None

    @property
    def differentiable(self) -> bool:
        return self.vjp is not None

    def __call__(self, *arrays: jax.Array) -> jax.Array:
        return self.apply(*arrays)

    def __repr__(self) -> str:  # keep plans printable
        return f"Kernel<{self.name}>"


_REGISTRY: dict[str, Kernel] = {}


def register(kernel: Kernel) -> Kernel:
    _REGISTRY[kernel.name] = kernel
    return kernel


def get_kernel(name: str) -> Kernel:
    try:
        return _REGISTRY[name]
    except KeyError as exc:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {sorted(_REGISTRY)}"
        ) from exc


def registered_kernels() -> Sequence[str]:
    return sorted(_REGISTRY)


def _prod(xs: Sequence[int]) -> int:
    return math.prod(xs) if xs else 1


def _same_bound(*bounds: Bound) -> Bound:
    first = bounds[0]
    for b in bounds[1:]:
        if tuple(b) != tuple(first):
            raise ValueError(f"bound mismatch: {bounds}")
    return tuple(first)


# --------------------------------------------------------------------------
# Structural gradient kernels (operand projections).  ``gradL``/``gradLNeg``
# pass through (resp. negate) their first operand and ignore the second —
# the VJP images of the linear elementwise kernels.  They exist so that the
# backward graph stays inside the algebra: the shape/keys of the ignored
# operand still drive the join's key alignment.
# --------------------------------------------------------------------------

gradL = register(Kernel(
    name="gradL", arity=2,
    apply=lambda a, b: a,
    out_bound=lambda bl, br: tuple(bl),
    flops=lambda *bs: 0,
))

gradLNeg = register(Kernel(
    name="gradLNeg", arity=2,
    apply=lambda a, b: -a,
    out_bound=lambda bl, br: tuple(bl),
    flops=lambda *bs: _prod(bs[0]),
))

# broadcast-back of an aggregated cotangent: second operand wins (the first
# is a shape donor keyed by the pre-aggregation key space)
gradR = register(Kernel(
    name="gradR", arity=2,
    apply=lambda a, b: b,
    out_bound=lambda bl, br: tuple(br),
    flops=lambda *bs: 0,
))


# --------------------------------------------------------------------------
# Elementwise binary kernels
# --------------------------------------------------------------------------

matAdd = register(Kernel(
    name="matAdd", arity=2,
    apply=lambda a, b: a + b,
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    is_associative=True, identity=0.0,
    reduce=lambda x, axes: jnp.sum(x, axis=axes),
    vjp=(JoinVjp("gradL"), JoinVjp("gradL")),
))

matSub = register(Kernel(
    name="matSub", arity=2,
    apply=lambda a, b: a - b,
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    vjp=(JoinVjp("gradL"), JoinVjp("gradLNeg")),
))

elemMul = register(Kernel(
    name="elemMul", arity=2,
    apply=lambda a, b: a * b,
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    is_associative=True, identity=1.0,
    reduce=lambda x, axes: jnp.prod(x, axis=axes),
    vjp=(JoinVjp("elemMul"), JoinVjp("elemMul", cot_first=False)),
))

elemDiv = register(Kernel(
    name="elemDiv", arity=2,
    apply=lambda a, b: a / b,
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    # dA = g / b; dB needs both operands (−g·a/b²) — not JoinVjp-shaped
    vjp=(JoinVjp("elemDiv"), None),
))

# exact-equality indicator — the argmax-mask primitive behind the
# max/min aggregation VJP rules (ties get the mask at every maximal
# entry; the autodiff rule divides by the tie count, matching jax)
eqMask = register(Kernel(
    name="eqMask", arity=2,
    apply=lambda a, b: (a == b).astype(a.dtype),
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
))

elemMax = register(Kernel(
    name="elemMax", arity=2,
    apply=lambda a, b: jnp.maximum(a, b),
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    is_associative=True, identity=-jnp.inf,
    reduce=lambda x, axes: jnp.max(x, axis=axes),
))

elemMin = register(Kernel(
    name="elemMin", arity=2,
    apply=lambda a, b: jnp.minimum(a, b),
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    is_associative=True, identity=jnp.inf,
    reduce=lambda x, axes: jnp.min(x, axis=axes),
))


# --------------------------------------------------------------------------
# Matmul family (rank-2 bounds). flops are 2*m*k*n (mult + add).
# --------------------------------------------------------------------------

def _mm_bound(bl: Bound, br: Bound) -> Bound:
    if len(bl) != 2 or len(br) != 2 or bl[1] != br[0]:
        raise ValueError(f"matMul bound mismatch {bl} x {br}")
    return (bl[0], br[1])


matMul = register(Kernel(
    name="matMul", arity=2,
    apply=lambda a, b: jnp.matmul(a, b),
    out_bound=_mm_bound,
    flops=lambda bl, br: 2 * bl[0] * bl[1] * br[1],
    # dA = G @ Bᵀ, dB = Aᵀ @ G — the closure of the matmul family under
    # differentiation is exactly the paper's §5.3 kernel triple.
    vjp=(JoinVjp("matTranMulR"), JoinVjp("matTranMulL", cot_first=False)),
))

# A^T @ B  (the backprop weight-gradient kernel of paper §5.3)
matTranMulL = register(Kernel(
    name="matTranMulL", arity=2,
    apply=lambda a, b: jnp.einsum("...ij,...ik->...jk", a, b),
    out_bound=lambda bl, br: (bl[1], br[1]),
    flops=lambda bl, br: 2 * bl[0] * bl[1] * br[1],
    # out = AᵀB: dA = B @ Gᵀ, dB = A @ G
    vjp=(JoinVjp("matTranMulR", cot_first=False),
         JoinVjp("matMul", cot_first=False)),
))

# A @ B^T  (the backprop activation-gradient kernel of paper §5.3)
matTranMulR = register(Kernel(
    name="matTranMulR", arity=2,
    apply=lambda a, b: jnp.einsum("...ij,...kj->...ik", a, b),
    out_bound=lambda bl, br: (bl[0], br[0]),
    flops=lambda bl, br: 2 * bl[0] * bl[1] * br[0],
    # out = ABᵀ: dA = G @ B, dB = Gᵀ @ A
    vjp=(JoinVjp("matMul"), JoinVjp("matTranMulL")),
))

# dQ of matVecSub: the cotangent summed over the broadcast (row) dim,
# keeping the query's (1, d) bound.  Ignores its second operand.
_sumRowsKeep = register(Kernel(
    name="sumRowsKeep", arity=2,
    apply=lambda g, x: jnp.sum(g, axis=-2, keepdims=True),
    out_bound=lambda bg, bx: (1,) + tuple(bg[-1:]),
    flops=lambda bg, bx: _prod(bg),
))

# x (row vector batch) - X : matrix-vector subtraction from paper §5.2
matVecSub = register(Kernel(
    name="matVecSub", arity=2,
    apply=lambda q, x: q - x,
    out_bound=lambda bq, bx: bx,
    flops=lambda bq, bx: _prod(bx),
    vjp=(JoinVjp("sumRowsKeep"), JoinVjp("gradLNeg")),
))


# --------------------------------------------------------------------------
# Unary kernels
# --------------------------------------------------------------------------

idOp = register(Kernel(
    name="idOp", arity=1,
    apply=lambda a: a,
    out_bound=lambda b: tuple(b),
    flops=lambda b: 0,
    distributes_over=("matAdd", "elemMul", "elemMax", "elemMin"),
    vjp=lambda x, y, g: g,
))

relu = register(Kernel(
    name="relu", arity=1,
    apply=lambda a: jnp.maximum(a, 0.0),
    out_bound=lambda b: tuple(b),
    flops=lambda b: _prod(b),
    # relu'(z)·g — reluGrad on the *pre-activation* child (== reluGrad on
    # the output away from 0, which is how §5.3 writes it by hand)
    vjp=lambda x, y, g: x.map("reluGrad") * g,
))

reluGrad = register(Kernel(
    name="reluGrad", arity=1,
    apply=lambda a: (a > 0.0).astype(a.dtype),
    out_bound=lambda b: tuple(b),
    flops=lambda b: _prod(b),
))

sigmoid = register(Kernel(
    name="sigmoid", arity=1,
    apply=lambda a: jax.nn.sigmoid(a),
    out_bound=lambda b: tuple(b),
    flops=lambda b: 4 * _prod(b),
    # σ'(z) = σ(z)(1-σ(z)) — recomputed from the forward *output*, which
    # the autodiff transform passes in as the shared DAG node
    vjp=lambda x, y, g: y.map("sigmoidGrad") * g,
))

sigmoidGrad = register(Kernel(
    name="sigmoidGrad", arity=1,
    apply=lambda s: s * (1.0 - s),
    out_bound=lambda b: tuple(b),
    flops=lambda b: 2 * _prod(b),
))

def _diag(a: jax.Array) -> jax.Array:
    # diagonal of the last two dims, batched over leading dims
    return jnp.diagonal(a, axis1=-2, axis2=-1)

def make_diag_embed(rows: int, cols: int) -> Kernel:
    """Scatter a diagonal vector back into a (rows, cols) zero matrix —
    the VJP image of ``diag``."""
    idx = min(rows, cols)

    def _apply(a: jax.Array) -> jax.Array:
        out = jnp.zeros(a.shape[:-1] + (rows, cols), a.dtype)
        rng = jnp.arange(idx)
        return out.at[..., rng, rng].set(a[..., :idx])

    return Kernel(
        name=f"diagEmbed({rows},{cols})", arity=1,
        apply=_apply,
        out_bound=lambda b: (rows, cols),
        flops=lambda b: 0,
    )


diag = register(Kernel(
    name="diag", arity=1,
    apply=_diag,
    out_bound=lambda b: (min(b[-2], b[-1]),),
    flops=lambda b: 0,
    # diag(A + B) == diag(A) + diag(B): exactly the paper's R1-7 example.
    distributes_over=("matAdd",),
    vjp=lambda x, y, g: g.map(make_diag_embed(*x.bound[-2:])),
))


def make_row_broadcast(n: int) -> Kernel:
    """Repeat along a trailing dim of size ``n`` — the VJP image of
    ``rowSum``."""
    return Kernel(
        name=f"rowBroadcast({n})", arity=1,
        apply=lambda a: jnp.broadcast_to(a[..., None], a.shape + (n,)),
        out_bound=lambda b: tuple(b) + (n,),
        flops=lambda b: 0,
        distributes_over=("matAdd",),
    )


rowSum = register(Kernel(
    name="rowSum", arity=1,
    apply=lambda a: jnp.sum(a, axis=-1),
    out_bound=lambda b: tuple(b[:-1]),
    flops=lambda b: _prod(b),
    distributes_over=("matAdd",),
    vjp=lambda x, y, g: g.map(make_row_broadcast(x.bound[-1])),
))


def make_scale_mul(eta: float) -> Kernel:
    """scaleMul_(eta) from paper §5.3 — parameterized, hence a factory."""
    return Kernel(
        name=f"scaleMul({eta})", arity=1,
        apply=lambda a: a * eta,
        out_bound=lambda b: tuple(b),
        flops=lambda b: _prod(b),
        distributes_over=("matAdd",),
        vjp=lambda x, y, g: g.map(make_scale_mul(eta)),
    )


# --------------------------------------------------------------------------
# Optimizer update-rule kernels (repro.core.train).  Updates are TRA
# expressions over parameter / gradient / optimizer-state relations, so the
# per-block math lives here: fused axpy for SGD, fused moment updates for
# momentum / AdamW, and the scalar-broadcast machinery that threads the
# step count (bias correction) through the plan as a relation instead of a
# recompile-forcing kernel constant.
# --------------------------------------------------------------------------

def _scale_by_apply(a: jax.Array, s: jax.Array) -> jax.Array:
    # s is a scalar-relation block: trailing (1, 1) bound under any
    # leading key dims.  Drop the bound and re-append singletons matching
    # a's bound rank so broadcasting can never GROW a's rank (a rank-1
    # a-block times a (1, 1) s-block would otherwise come out rank-2).
    if s.ndim < 2 or s.shape[-2:] != (1, 1):
        raise ValueError(
            f"scaleBy expects a scalar-relation right operand with "
            f"(1, 1) blocks, got block shape {s.shape[-2:]}")
    s2 = s[..., 0, 0]
    return a * s2.reshape(s2.shape + (1,) * (a.ndim - s2.ndim))


# multiply every array by a co-joined scalar block (bound (1, 1) on the
# right — the scalar-relation carrier type).  Used by Expr.scale_by to
# apply per-step scalars (bias corrections, schedules) without baking
# them into kernel names.
scaleBy = register(Kernel(
    name="scaleBy", arity=2,
    apply=_scale_by_apply,
    out_bound=lambda bl, br: tuple(bl),
    flops=lambda *bs: _prod(bs[0]),
    vjp=(JoinVjp("scaleBy"), None),
))

# t → t + 1: the step-counter update (a (1,)-keyed scalar relation)
stepIncr = register(Kernel(
    name="stepIncr", arity=1,
    apply=lambda t: t + 1.0,
    out_bound=lambda b: tuple(b),
    flops=lambda b: _prod(b),
))


def make_axpy(alpha: float) -> Kernel:
    """Fused ``a + alpha·b`` — the SGD / update-application kernel
    (one join instead of a scale-map plus a subtract-join)."""
    return Kernel(
        name=f"axpy({alpha})", arity=2,
        apply=lambda a, b: a + alpha * b,
        out_bound=lambda bl, br: tuple(bl),
        flops=lambda *bs: 2 * _prod(bs[0]),
        vjp=(JoinVjp("gradL"), JoinVjp(make_scale_mul_bin(alpha))),
    )


def make_scale_mul_bin(alpha: float) -> Kernel:
    """``alpha·a`` ignoring the second operand — the axpy VJP image."""
    return Kernel(
        name=f"scaleMulBin({alpha})", arity=2,
        apply=lambda a, b: alpha * a,
        out_bound=lambda bl, br: tuple(bl),
        flops=lambda *bs: _prod(bs[0]),
    )


def make_momentum(mu: float) -> Kernel:
    """Fused heavy-ball buffer update ``mu·m + g`` (optax trace)."""
    return Kernel(
        name=f"momentum({mu})", arity=2,
        apply=lambda m, g: mu * m + g,
        out_bound=_same_bound,
        flops=lambda *bs: 2 * _prod(bs[0]),
    )


def make_ema(beta: float) -> Kernel:
    """Fused first-moment update ``beta·m + (1−beta)·g`` (Adam m)."""
    return Kernel(
        name=f"ema({beta})", arity=2,
        apply=lambda m, g: beta * m + (1.0 - beta) * g,
        out_bound=_same_bound,
        flops=lambda *bs: 3 * _prod(bs[0]),
    )


def make_ema_sq(beta: float) -> Kernel:
    """Fused second-moment update ``beta·v + (1−beta)·g²`` (Adam v)."""
    return Kernel(
        name=f"emaSq({beta})", arity=2,
        apply=lambda v, g: beta * v + (1.0 - beta) * g * g,
        out_bound=_same_bound,
        flops=lambda *bs: 4 * _prod(bs[0]),
    )


def make_adam_dir(eps: float) -> Kernel:
    """Adam update direction ``m̂ / (√v̂ + eps)`` over co-keyed moments."""
    return Kernel(
        name=f"adamDir({eps})", arity=2,
        apply=lambda m, v: m / (jnp.sqrt(v) + eps),
        out_bound=_same_bound,
        flops=lambda *bs: 3 * _prod(bs[0]),
    )


def make_bias_corr(beta: float) -> Kernel:
    """``1 / (1 − betaᵗ)`` from the step-count relation — the Adam bias
    correction as a *data-dependent* scalar, so one compiled train-step
    program serves every step (no per-step kernel constants)."""
    return Kernel(
        name=f"biasCorr({beta})", arity=1,
        apply=lambda t: 1.0 / (1.0 - beta ** t),
        out_bound=lambda b: tuple(b),
        flops=lambda b: 3 * _prod(b),
    )


def _bce_sum(p: jax.Array, y: jax.Array) -> jax.Array:
    """Blockwise binary-cross-entropy partial sum over rank-2 blocks:
    Σ over the block of −[y·log(p) + (1−y)·log(1−p)] as a (1, 1) array,
    so the total loss is the matAdd aggregation of the blocks (§5.3
    training loss)."""
    pc = jnp.clip(p, 1e-7, 1.0 - 1e-7)
    ll = y * jnp.log(pc) + (1.0 - y) * jnp.log1p(-pc)
    return jnp.sum(-ll, axis=(-2, -1), keepdims=True)


bceSum = register(Kernel(
    name="bceSum", arity=2,
    apply=_bce_sum,
    out_bound=lambda bl, br: (1, 1),
    flops=lambda *bs: 8 * _prod(bs[0]),
))


def make_transpose() -> Kernel:
    return Kernel(
        name="transpose", arity=1,
        apply=lambda a: jnp.swapaxes(a, -1, -2),
        out_bound=lambda b: (b[-1], b[-2]),
        flops=lambda b: 0,
        distributes_over=(),
        vjp=lambda x, y, g: g.map("transpose"),
    )


transpose = register(make_transpose())


# --------------------------------------------------------------------------
# (value, index) argmin machinery for the paper's §5.2 nearest-neighbour
# search.  ``toValIdx`` turns a (rows,) distance block into a (2,) array of
# [min_value, global_row_index]; ``minIndex`` is the associative combiner.
# --------------------------------------------------------------------------

def make_to_val_idx(rows_per_block: int) -> Kernel:
    def _apply(a: jax.Array) -> jax.Array:
        idx = jnp.argmin(a, axis=-1)
        val = jnp.min(a, axis=-1)
        return jnp.stack([val, idx.astype(a.dtype)], axis=-1)

    return Kernel(
        name=f"toValIdx({rows_per_block})", arity=1,
        apply=_apply,
        out_bound=lambda b: (2,),
        flops=lambda b: _prod(b),
    )


def _min_index(a: jax.Array, b: jax.Array) -> jax.Array:
    take_a = a[..., 0] <= b[..., 0]
    return jnp.where(take_a[..., None], a, b)


minIndex = register(Kernel(
    name="minIndex", arity=2,
    apply=_min_index,
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    is_associative=True,
))


# --------------------------------------------------------------------------
# Structural kernels used by Tile / Concat / replication (λ^L multi-map)
# --------------------------------------------------------------------------

def compose(outer: Kernel, inner: Kernel) -> Kernel:
    """Kernel composition (outer ∘ inner) — used by rules R1-2/R1-4/R1-7."""
    if inner.arity == 1:
        app = lambda *xs: outer.apply(inner.apply(*xs)) if outer.arity == 1 \
            else None
        if outer.arity != 1:
            raise ValueError("compose: outer of unary must be unary")
        return Kernel(
            name=f"{outer.name}∘{inner.name}", arity=1,
            apply=lambda a: outer.apply(inner.apply(a)),
            out_bound=lambda b: outer.out_bound(inner.out_bound(b)),
            flops=lambda b: inner.flops(b) + outer.flops(inner.out_bound(b)),
            distributes_over=tuple(
                set(outer.distributes_over) & set(inner.distributes_over)),
        )
    # outer unary applied to the result of a binary kernel
    if outer.arity != 1 or inner.arity != 2:
        raise ValueError("compose supports unary∘unary or unary∘binary")
    return Kernel(
        name=f"{outer.name}∘{inner.name}", arity=2,
        apply=lambda a, b: outer.apply(inner.apply(a, b)),
        out_bound=lambda bl, br: outer.out_bound(inner.out_bound(bl, br)),
        flops=lambda bl, br: inner.flops(bl, br)
        + outer.flops(inner.out_bound(bl, br)),
    )
