"""Kernel-function registry for the TRA/IA.

The paper's TRA is a family of *higher-order* functions: every algebra op
takes a kernel function over plain arrays (in the paper: an MKL/CUDA kernel).
Here kernels are jnp callables obeying one convention:

    kernel.apply operates on the LAST ``rank`` dims of its operands and
    broadcasts over any leading (key/batch) dims.

That convention is what lets the dense executor evaluate a join by aligning
key dims and issuing a *single* batched kernel call (which XLA then maps onto
the MXU) instead of looping over tuples like the paper's Python engine.

Each kernel carries the metadata the optimizer needs:
  * ``out_bound``   — array-type inference (bound of the output),
  * ``flops``       — exact flop count for the compute roofline term,
  * ``is_associative``/``identity``/``reduce`` — for aggregation kernels,
  * ``distributes_over`` — names of agg kernels it distributes over (R1-4 /
    R1-7 side conditions).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Bound = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Kernel:
    """A named array kernel usable inside TRA/IA operations."""

    name: str
    arity: int                                  # 1 or 2 operand arrays
    apply: Callable[..., jax.Array]
    out_bound: Callable[..., Bound]             # (*bounds) -> bound
    flops: Callable[..., int]                   # (*bounds) -> flop count
    is_associative: bool = False                # usable as an agg kernel
    identity: Optional[float] = None            # identity element for agg
    reduce: Optional[Callable[[jax.Array, Tuple[int, ...]], jax.Array]] = None
    distributes_over: Tuple[str, ...] = ()      # agg kernels f with k(f(a,b)) = f(k(a),k(b))

    def __call__(self, *arrays: jax.Array) -> jax.Array:
        return self.apply(*arrays)

    def __repr__(self) -> str:  # keep plans printable
        return f"Kernel<{self.name}>"


_REGISTRY: dict[str, Kernel] = {}


def register(kernel: Kernel) -> Kernel:
    _REGISTRY[kernel.name] = kernel
    return kernel


def get_kernel(name: str) -> Kernel:
    try:
        return _REGISTRY[name]
    except KeyError as exc:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {sorted(_REGISTRY)}"
        ) from exc


def registered_kernels() -> Sequence[str]:
    return sorted(_REGISTRY)


def _prod(xs: Sequence[int]) -> int:
    return math.prod(xs) if xs else 1


def _same_bound(*bounds: Bound) -> Bound:
    first = bounds[0]
    for b in bounds[1:]:
        if tuple(b) != tuple(first):
            raise ValueError(f"bound mismatch: {bounds}")
    return tuple(first)


# --------------------------------------------------------------------------
# Elementwise binary kernels
# --------------------------------------------------------------------------

matAdd = register(Kernel(
    name="matAdd", arity=2,
    apply=lambda a, b: a + b,
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    is_associative=True, identity=0.0,
    reduce=lambda x, axes: jnp.sum(x, axis=axes),
))

matSub = register(Kernel(
    name="matSub", arity=2,
    apply=lambda a, b: a - b,
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
))

elemMul = register(Kernel(
    name="elemMul", arity=2,
    apply=lambda a, b: a * b,
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    is_associative=True, identity=1.0,
    reduce=lambda x, axes: jnp.prod(x, axis=axes),
))

elemMax = register(Kernel(
    name="elemMax", arity=2,
    apply=lambda a, b: jnp.maximum(a, b),
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    is_associative=True, identity=-jnp.inf,
    reduce=lambda x, axes: jnp.max(x, axis=axes),
))

elemMin = register(Kernel(
    name="elemMin", arity=2,
    apply=lambda a, b: jnp.minimum(a, b),
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    is_associative=True, identity=jnp.inf,
    reduce=lambda x, axes: jnp.min(x, axis=axes),
))


# --------------------------------------------------------------------------
# Matmul family (rank-2 bounds). flops are 2*m*k*n (mult + add).
# --------------------------------------------------------------------------

def _mm_bound(bl: Bound, br: Bound) -> Bound:
    if len(bl) != 2 or len(br) != 2 or bl[1] != br[0]:
        raise ValueError(f"matMul bound mismatch {bl} x {br}")
    return (bl[0], br[1])


matMul = register(Kernel(
    name="matMul", arity=2,
    apply=lambda a, b: jnp.matmul(a, b),
    out_bound=_mm_bound,
    flops=lambda bl, br: 2 * bl[0] * bl[1] * br[1],
))

# A^T @ B  (the backprop weight-gradient kernel of paper §5.3)
matTranMulL = register(Kernel(
    name="matTranMulL", arity=2,
    apply=lambda a, b: jnp.einsum("...ij,...ik->...jk", a, b),
    out_bound=lambda bl, br: (bl[1], br[1]),
    flops=lambda bl, br: 2 * bl[0] * bl[1] * br[1],
))

# A @ B^T  (the backprop activation-gradient kernel of paper §5.3)
matTranMulR = register(Kernel(
    name="matTranMulR", arity=2,
    apply=lambda a, b: jnp.einsum("...ij,...kj->...ik", a, b),
    out_bound=lambda bl, br: (bl[0], br[0]),
    flops=lambda bl, br: 2 * bl[0] * bl[1] * br[0],
))

# x (row vector batch) - X : matrix-vector subtraction from paper §5.2
matVecSub = register(Kernel(
    name="matVecSub", arity=2,
    apply=lambda q, x: q - x,
    out_bound=lambda bq, bx: bx,
    flops=lambda bq, bx: _prod(bx),
))


# --------------------------------------------------------------------------
# Unary kernels
# --------------------------------------------------------------------------

idOp = register(Kernel(
    name="idOp", arity=1,
    apply=lambda a: a,
    out_bound=lambda b: tuple(b),
    flops=lambda b: 0,
    distributes_over=("matAdd", "elemMul", "elemMax", "elemMin"),
))

relu = register(Kernel(
    name="relu", arity=1,
    apply=lambda a: jnp.maximum(a, 0.0),
    out_bound=lambda b: tuple(b),
    flops=lambda b: _prod(b),
))

reluGrad = register(Kernel(
    name="reluGrad", arity=1,
    apply=lambda a: (a > 0.0).astype(a.dtype),
    out_bound=lambda b: tuple(b),
    flops=lambda b: _prod(b),
))

sigmoid = register(Kernel(
    name="sigmoid", arity=1,
    apply=lambda a: jax.nn.sigmoid(a),
    out_bound=lambda b: tuple(b),
    flops=lambda b: 4 * _prod(b),
))

def _diag(a: jax.Array) -> jax.Array:
    # diagonal of the last two dims, batched over leading dims
    return jnp.diagonal(a, axis1=-2, axis2=-1)

diag = register(Kernel(
    name="diag", arity=1,
    apply=_diag,
    out_bound=lambda b: (min(b[-2], b[-1]),),
    flops=lambda b: 0,
    # diag(A + B) == diag(A) + diag(B): exactly the paper's R1-7 example.
    distributes_over=("matAdd",),
))

rowSum = register(Kernel(
    name="rowSum", arity=1,
    apply=lambda a: jnp.sum(a, axis=-1),
    out_bound=lambda b: tuple(b[:-1]),
    flops=lambda b: _prod(b),
    distributes_over=("matAdd",),
))


def make_scale_mul(eta: float) -> Kernel:
    """scaleMul_(eta) from paper §5.3 — parameterized, hence a factory."""
    return Kernel(
        name=f"scaleMul({eta})", arity=1,
        apply=lambda a: a * eta,
        out_bound=lambda b: tuple(b),
        flops=lambda b: _prod(b),
        distributes_over=("matAdd",),
    )


def make_transpose() -> Kernel:
    return Kernel(
        name="transpose", arity=1,
        apply=lambda a: jnp.swapaxes(a, -1, -2),
        out_bound=lambda b: (b[-1], b[-2]),
        flops=lambda b: 0,
        distributes_over=(),
    )


transpose = register(make_transpose())


# --------------------------------------------------------------------------
# (value, index) argmin machinery for the paper's §5.2 nearest-neighbour
# search.  ``toValIdx`` turns a (rows,) distance block into a (2,) array of
# [min_value, global_row_index]; ``minIndex`` is the associative combiner.
# --------------------------------------------------------------------------

def make_to_val_idx(rows_per_block: int) -> Kernel:
    def _apply(a: jax.Array) -> jax.Array:
        idx = jnp.argmin(a, axis=-1)
        val = jnp.min(a, axis=-1)
        return jnp.stack([val, idx.astype(a.dtype)], axis=-1)

    return Kernel(
        name=f"toValIdx({rows_per_block})", arity=1,
        apply=_apply,
        out_bound=lambda b: (2,),
        flops=lambda b: _prod(b),
    )


def _min_index(a: jax.Array, b: jax.Array) -> jax.Array:
    take_a = a[..., 0] <= b[..., 0]
    return jnp.where(take_a[..., None], a, b)


minIndex = register(Kernel(
    name="minIndex", arity=2,
    apply=_min_index,
    out_bound=_same_bound,
    flops=lambda *bs: _prod(bs[0]),
    is_associative=True,
))


# --------------------------------------------------------------------------
# Structural kernels used by Tile / Concat / replication (λ^L multi-map)
# --------------------------------------------------------------------------

def compose(outer: Kernel, inner: Kernel) -> Kernel:
    """Kernel composition (outer ∘ inner) — used by rules R1-2/R1-4/R1-7."""
    if inner.arity == 1:
        app = lambda *xs: outer.apply(inner.apply(*xs)) if outer.arity == 1 \
            else None
        if outer.arity != 1:
            raise ValueError("compose: outer of unary must be unary")
        return Kernel(
            name=f"{outer.name}∘{inner.name}", arity=1,
            apply=lambda a: outer.apply(inner.apply(a)),
            out_bound=lambda b: outer.out_bound(inner.out_bound(b)),
            flops=lambda b: inner.flops(b) + outer.flops(inner.out_bound(b)),
            distributes_over=tuple(
                set(outer.distributes_over) & set(inner.distributes_over)),
        )
    # outer unary applied to the result of a binary kernel
    if outer.arity != 1 or inner.arity != 2:
        raise ValueError("compose supports unary∘unary or unary∘binary")
    return Kernel(
        name=f"{outer.name}∘{inner.name}", arity=2,
        apply=lambda a, b: outer.apply(inner.apply(a, b)),
        out_bound=lambda bl, br: outer.out_bound(inner.out_bound(bl, br)),
        flops=lambda bl, br: inner.flops(bl, br)
        + outer.flops(inner.out_bound(bl, br)),
    )
