"""Exact cost model for IA plans (paper §4.3).

Because uniqueness + continuity hold (and our masks make even the
post-filter cardinalities *exact*), no estimation is involved:

    tuples(R)  = #valid keys           (∏ fᵢ when continuous)
    floats(R)  = tuples × ∏ bᵢ  ×  dup_multiplicity

    cost(BCAST(R)) = floats(R) × s       (every tuple to every site)
    cost(SHUF(R))  = floats(R)           (every tuple moves once)

``dup_multiplicity`` covers the transient duplicate-key state inside a
two-phase aggregation (R2-5): a relation whose placement has ``dup_axes``
holds one partial copy per site along those axes.  A SHUF of that state is
a reduce-scatter, a BCAST of it is an all-reduce; both formulas then match
the paper's accounting of "every (partial) tuple moves".

Beyond the paper we also expose the *compute* side (exact kernel flops) and
*roofline seconds* against a hardware model — used by the §Perf loop — but
plan *selection* defaults to the paper's pure-communication metric so the
reproduction stays faithful.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

from repro.core.plan import (Bcast, FusedJoinAgg, IANode, LocalAgg,
                             LocalJoin, LocalMap, Shuf, TypeInfo,
                             _join_types, infer, postorder)


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """TPU v5e defaults (per chip)."""

    peak_flops: float = 197e12          # bf16 FLOP/s
    hbm_bw: float = 819e9               # bytes/s
    ici_bw: float = 50e9                # bytes/s per link
    bytes_per_float: int = 4


TPU_V5E = HardwareModel()


@dataclasses.dataclass
class NodeCost:
    node: str
    comm_floats: int = 0
    flops: int = 0
    # floats a node *materializes* beyond its inputs/output (an unfused
    # LocalJoin builds the whole broadcasted grid; FusedJoinAgg streams it).
    # Not part of the paper's §4.3 metric — used as a memory tiebreak.
    tmp_floats: int = 0


@dataclasses.dataclass
class CostReport:
    comm_floats: int
    flops: int
    per_node: List[NodeCost]
    tmp_floats: int = 0

    def comm_seconds(self, hw: HardwareModel = TPU_V5E,
                     n_sites: int = 1) -> float:
        return (self.comm_floats * hw.bytes_per_float) / (hw.ici_bw * n_sites)

    def compute_seconds(self, hw: HardwareModel = TPU_V5E,
                        n_sites: int = 1) -> float:
        return self.flops / (hw.peak_flops * n_sites)

    def __str__(self) -> str:
        lines = [f"total comm floats: {self.comm_floats:,}",
                 f"total flops:       {self.flops:,}"]
        for nc in self.per_node:
            if nc.comm_floats or nc.flops:
                lines.append(f"  {nc.node:<40} comm={nc.comm_floats:<14,} "
                             f"flops={nc.flops:,}")
        return "\n".join(lines)


def _dup_multiplicity(info: TypeInfo, axis_sizes: Dict[str, int]) -> int:
    if info.placement is None or not info.placement.dup_axes:
        return 1
    return math.prod(axis_sizes[a] for a in info.placement.dup_axes)


def floats_of(info: TypeInfo, axis_sizes: Dict[str, int]) -> int:
    return info.valid_floats * _dup_multiplicity(info, axis_sizes)


def move_floats(f_logical: int, src, tgt, axis_sizes: Dict[str, int],
                accounting: str = "wire") -> int:
    """Floats on the wire to move a relation from placement src → tgt.

    ``accounting="paper"`` is the paper's §4.3 rule verbatim: SHUF = f,
    BCAST = f×s (used to reproduce Tables 4/6/9 exactly).

    ``accounting="wire"`` (default, used for plan selection) prices each
    transition by actual bytes received: per site, the floats it needs
    under ``tgt`` minus the useful overlap it already holds under ``src``,
    summed over sites.  This correctly charges an axis *un-sharding*
    (all-gather) ``≈ f × axis_size`` where the paper's flat SHUF=f under-
    charges it, reduces to the paper's numbers for the pure cases
    (full-partition shuffle = f; broadcast of a partitioned relation ≈
    f×s; already-in-place = 0), and prices the two-phase aggregation's
    reduce-scatter / all-reduce at their ring-collective wire volumes.
    """
    s = math.prod(axis_sizes.values()) if axis_sizes else 1
    src_axes = {} if src is None or src.kind != "partitioned" else \
        {ax: d for d, ax in zip(src.dims, src.axes)}
    tgt_axes = {} if tgt is None or tgt.kind != "partitioned" else \
        {ax: d for d, ax in zip(tgt.dims, tgt.axes)}
    dup = () if src is None else tuple(src.dup_axes)

    if accounting == "paper":
        f = f_logical
        if tgt is None or tgt.kind == "replicated":
            return f * s
        return f

    cost = 0
    # Phase 1 — resolve pending duplicate partials (R2-5 second phase):
    # a reduce(-scatter) over each dup axis moves every partial once.
    src_eff = dict(src_axes)
    for ax in dup:
        size = axis_sizes.get(ax, 1)
        cost += f_logical * max(size - 1, 0)
        if ax in tgt_axes:
            src_eff[ax] = tgt_axes[ax]      # scattered straight into place
        # else: post-reduce the value is replicated along ax (all-reduce)

    # Phase 2 — per-site need vs overlap (intersection of constraints).
    if src_eff == tgt_axes:
        return cost
    need = 1.0       # fraction of the relation each site needs under tgt
    overlap = 1.0    # fraction it already holds that is *useful*
    for ax, size in axis_sizes.items():
        sd, td = src_eff.get(ax), tgt_axes.get(ax)
        if td is not None:
            need /= size
        if sd is not None and sd == td:
            overlap /= size                  # aligned constraint (shared)
        else:
            if sd is not None:
                overlap /= size              # holdings cut by src shard
            if td is not None:
                overlap /= size              # needs cut independently
    received = max(0.0, need - overlap)
    return cost + int(round(f_logical * s * received))


def cost_plan(root: IANode, axis_sizes: Dict[str, int],
              accounting: str = "wire") -> CostReport:
    """Exact communication + compute cost of a physical plan."""
    from repro.core.plan import as_node
    root = as_node(root)
    cache: Dict[int, TypeInfo] = {}
    infer(root, cache=cache)
    s = math.prod(axis_sizes.values()) if axis_sizes else 1

    per_node: List[NodeCost] = []
    total_comm = 0
    total_flops = 0
    for n in postorder(root):
        ti = cache[id(n)]
        nc = NodeCost(node=type(n).__name__)
        if isinstance(n, Bcast):
            child = cache[id(n.child)]
            if child.placement is not None and child.placement.is_replicated:
                moved = 0  # R2-1: broadcast of a replicated relation is free
            else:
                moved = move_floats(child.valid_floats, child.placement,
                                    None, axis_sizes, accounting)
            nc.comm_floats = moved
            nc.node += "→ALL"
        elif isinstance(n, Shuf):
            child = cache[id(n.child)]
            nc.comm_floats = move_floats(
                child.valid_floats, child.placement, ti.placement,
                axis_sizes, accounting)
            nc.node += f"→{ti.placement.describe()}"
        elif isinstance(n, LocalJoin):
            lt, rt = cache[id(n.left)], cache[id(n.right)]
            nc.flops = ti.valid_tuples * n.kernel.flops(lt.rtype.bound,
                                                        rt.rtype.bound)
            nc.tmp_floats = ti.valid_floats     # materialized join grid
        elif isinstance(n, LocalAgg):
            child = cache[id(n.child)]
            combines = max(child.valid_tuples - ti.valid_tuples, 0)
            if n.kernel.arity == 2:
                nc.flops = combines * n.kernel.flops(child.rtype.bound,
                                                     child.rtype.bound)
        elif isinstance(n, FusedJoinAgg):
            lt, rt = cache[id(n.left)], cache[id(n.right)]
            joint = _join_types(lt, rt, n.join_keys_l, n.join_keys_r,
                                n.join_kernel)
            nc.flops = joint.valid_tuples * n.join_kernel.flops(
                lt.rtype.bound, rt.rtype.bound)
            if n.agg_kernel.arity == 2:
                combines = max(joint.valid_tuples - ti.valid_tuples, 0)
                nc.flops += combines * n.agg_kernel.flops(joint.rtype.bound,
                                                          joint.rtype.bound)
            # streamed: output accumulator + one grid slice in flight
            nc.tmp_floats = 2 * ti.valid_floats
        elif isinstance(n, LocalMap):
            if n.kernel.name != "idOp":
                nc.flops = (cache[id(n.child)].valid_tuples
                            * n.kernel.flops(cache[id(n.child)].rtype.bound))
        per_node.append(nc)
        total_comm += nc.comm_floats
        total_flops += nc.flops
    total_tmp = sum(nc.tmp_floats for nc in per_node)
    return CostReport(total_comm, total_flops, per_node, total_tmp)


def comm_cost(root: IANode, axis_sizes: Dict[str, int],
              accounting: str = "wire") -> int:
    """The plan-selection metric: floats moved (wire-accurate by default;
    pass accounting="paper" for the paper's verbatim §4.3 rules)."""
    return cost_plan(root, axis_sizes, accounting).comm_floats


# ==========================================================================
# Compile-time liveness: peak device bytes of a plan evaluation
# ==========================================================================

def _itemsize(rtype) -> int:
    import numpy as np
    try:
        return np.dtype(rtype.dtype).itemsize
    except TypeError:
        return 4


def plan_peak_bytes(roots, *, fuse: bool = True) -> int:
    """Estimated peak live device bytes to evaluate ``roots``.

    Walks the shared DAG in evaluation (postorder) order with exact
    reference counts: a node's bytes stay live until its last consumer has
    evaluated; root outputs are never released.  Relations are priced at
    their *dense* allocation (``nfloats × itemsize`` — masks do not shrink
    the array XLA materializes).  With ``fuse=True`` (the Engine default)
    a ``TraAgg(TraJoin)`` pair that :func:`repro.core.tra.can_fuse`
    accepts — and any physical :class:`FusedJoinAgg` — never materializes
    the join grid; the streamed contraction instead holds the output
    accumulator plus one merged partial, charged as ``2 × out_bytes``.

    This is the estimator behind ``Engine(memory_budget=...)``: plans
    whose peak exceeds the budget are routed through the host relation
    store (:mod:`repro.store`) instead of evaluated resident.
    """
    from repro.core.plan import TraAgg, TraJoin, as_node, children
    from repro.core.tra import can_fuse
    if not isinstance(roots, (tuple, list)):
        roots = (roots,)
    roots = tuple(as_node(r) for r in roots)
    cache: Dict[int, TypeInfo] = {}
    for r in roots:
        infer(r, cache=cache)
    order, seen = [], set()
    for r in roots:
        for n in postorder(r):
            if id(n) not in seen:
                seen.add(id(n))
                order.append(n)

    consumers: Dict[int, int] = {}
    for n in order:
        for c in children(n):
            consumers[id(c)] = consumers.get(id(c), 0) + 1

    fused = set()
    for n in order:
        if isinstance(n, FusedJoinAgg):
            continue                    # inherently streamed already
        if (fuse and isinstance(n, TraAgg) and isinstance(n.child, TraJoin)
                and consumers.get(id(n.child), 0) == 1
                and can_fuse(n.child.kernel, n.kernel)):
            fused.add(id(n.child))

    def nbytes(n) -> int:
        ti = cache[id(n)]
        return ti.rtype.nfloats * _itemsize(ti.rtype)

    def eff_children(n):
        out = []
        for c in children(n):
            if id(c) in fused:
                out.extend(children(c))
            else:
                out.append(c)
        return out

    refs: Dict[int, int] = {}
    for n in order:
        if id(n) in fused:
            continue
        for c in eff_children(n):
            refs[id(c)] = refs.get(id(c), 0) + 1
    for r in roots:
        refs[id(r)] = refs.get(id(r), 0) + 1    # outputs never release

    live: Dict[int, int] = {}
    cur = peak = 0
    for n in order:
        if id(n) in fused:
            continue
        b = nbytes(n)
        streamed_contraction = isinstance(n, FusedJoinAgg) or (
            isinstance(n, TraAgg) and id(n.child) in fused)
        tmp = b if streamed_contraction else 0
        peak = max(peak, cur + b + tmp)
        cur += b
        live[id(n)] = b
        for c in eff_children(n):
            refs[id(c)] -= 1
            if refs[id(c)] == 0:
                cur -= live.pop(id(c), 0)
    return max(peak, cur)
