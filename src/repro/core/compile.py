"""TRA → IA compiler (paper §4.1, Table 1).

Produces the *default* physical plan; the optimizer in
:mod:`repro.core.optimize` then rewrites it cost-based.  The mapping is the
paper's Table 1 verbatim:

    Σ_(gb,op)(R)        ↦ Σᴸ_(gb,op)(SHUF_(gb)(R))
    ⋈_(jl,jr,op)(L, R)  ↦ ⋈ᴸ_(jl,jr,op)(BCAST(L), R)
    ReKey_(f)(R)        ↦ λᴸ_(f, idOp)(R)
    σ_(f)(R)            ↦ σᴸ_(f)(R)
    λ_(f)(R)            ↦ λᴸ_(idOp, f)(R)
    Tile / Concat       ↦ LocalTile / Σᴸ∘SHUF (LocalConcat after SHUF on the
                          complement key dims)
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from repro.core.kernels_registry import get_kernel
from repro.core.plan import (Bcast, IAConst, IAInput, IANode, LocalAgg,
                             LocalConcat, LocalFilter, LocalJoin, LocalMap,
                             LocalPad, LocalTile, Placement, Shuf, TraAgg,
                             TraConcat, TraConst, TraFilter, TraInput,
                             TraJoin, TraNode, TraPad, TraReKey, TraTile,
                             TraTransform, infer)


def compile_tra(node: TraNode,
                input_placements: Optional[Dict[str, Placement]] = None,
                site_axes: Tuple[str, ...] = ("sites",),
                _cache: Optional[dict] = None) -> IANode:
    """Compile a logical plan to the Table-1 default physical plan."""
    from repro.core.plan import as_node
    node = as_node(node)
    placements = input_placements or {}
    cache = _cache if _cache is not None else {}
    if id(node) in cache:
        return cache[id(node)]

    def rec(n):
        return compile_tra(n, placements, site_axes, cache)

    def shuf_dims(dims: Sequence[int]) -> Tuple[Tuple[int, ...],
                                                Tuple[str, ...]]:
        dims = tuple(dims)[:len(site_axes)]
        return dims, tuple(site_axes[:len(dims)])

    out: IANode
    if isinstance(node, TraInput):
        out = IAInput(node.name, node.rtype,
                      placements.get(node.name, Placement.replicated()))
    elif isinstance(node, TraConst):
        out = IAConst(node.rtype, node.fill, Placement.replicated())
    elif isinstance(node, TraPad):
        child = rec(node.child)
        if tuple(node.key_shape) != infer(node.child).rtype.key_shape:
            # growing a frontier is only local on a replicated child
            child = Bcast(child)
        out = LocalPad(child, tuple(node.key_shape))
    elif isinstance(node, TraJoin):
        out = LocalJoin(Bcast(rec(node.left)), rec(node.right),
                        node.join_keys_l, node.join_keys_r, node.kernel)
    elif isinstance(node, TraAgg):
        # Table 1 always re-shuffles on the group-by keys; an empty group-by
        # list shuffles to a single site (SINGLE placement).  The optimizer
        # later removes provably-redundant shuffles (R2-4) or splits the
        # aggregation in two phases (R2-5).
        dims, axes = shuf_dims(node.group_by)
        child = Shuf(rec(node.child), dims, axes)
        out = LocalAgg(child, node.group_by, node.kernel)
    elif isinstance(node, TraReKey):
        out = LocalMap(rec(node.child), node.key_func, get_kernel("idOp"),
                       tag=node.tag)
    elif isinstance(node, TraFilter):
        out = LocalFilter(rec(node.child), node.bool_func, tag=node.tag)
    elif isinstance(node, TraTransform):
        out = LocalMap(rec(node.child), None, node.kernel)
    elif isinstance(node, TraTile):
        out = LocalTile(rec(node.child), node.tile_dim, node.tile_size)
    elif isinstance(node, TraConcat):
        k = infer(node.child).rtype.key_arity
        complement = tuple(d for d in range(k) if d != node.key_dim)
        dims, axes = shuf_dims(complement)
        child = Shuf(rec(node.child), dims, axes)
        out = LocalConcat(child, node.key_dim, node.array_dim)
    else:
        raise TypeError(type(node))
    cache[id(node)] = out
    return out
