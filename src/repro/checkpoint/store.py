"""Atomic, versioned, async-capable checkpointing.

Layout::

    <dir>/step_000123/
        shard_00000.npz       # this host's param/opt leaves (flattened)
        meta.json             # tree structure, shapes, dtypes, extra state
        COMMIT                # written last — a step without it is garbage

* **Atomic** — writers stage into ``step_…​.tmp`` and ``os.rename`` into
  place after the COMMIT marker is inside; readers ignore uncommitted or
  partial steps, so a crash mid-save can never corrupt restore.
* **Versioned** — ``keep`` most recent committed steps are retained.
* **Async** — ``save_async`` snapshots to host memory synchronously
  (device→host copy) and writes in a background thread, overlapping I/O
  with the next training steps; ``wait()`` joins before the next save.
* **Elastic** — arrays are saved *unsharded* per leaf (gathered to host),
  so a restore may target any mesh/topology: the runtime re-shards on
  load (tested by the elastic re-mesh test).

On a real multi-host pod each host writes only its addressable shards
(``process_index`` in the shard filename); this single-process build
always writes shard 0 but keeps the full layout.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:09d}")


class CheckpointStore:
    def __init__(self, base: str, keep: int = 3):
        self.base = base
        self.keep = keep
        os.makedirs(base, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._exc: Optional[BaseException] = None

    # -- write -------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict] = None) -> str:
        leaves, treedef = _flatten(tree)
        return self._write(step, leaves, treedef, extra or {})

    def save_async(self, step: int, tree,
                   extra: Optional[Dict] = None) -> None:
        """Snapshot now (host copy), write in the background.

        A failed background write surfaces here (or at ``wait()``) on the
        *next* call — never silently: a swallowed I/O error would leave no
        committed step while the trainer believes it is checkpointed.
        """
        self.wait()
        leaves, treedef = _flatten(tree)   # device→host; blocking but fast
        extra = dict(extra or {})

        def work():
            try:
                self._write(step, leaves, treedef, extra)
            except BaseException as e:      # surfaced by the next wait()
                self._exc = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Join the pending background write; re-raise its failure."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def _write(self, step: int, leaves, treedef, extra: Dict) -> str:
        final = _step_dir(self.base, step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "shard_00000.npz"),
                 **{f"leaf_{i}": l for i, l in enumerate(leaves)})
        meta = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": _treedef_token(treedef),
            "extra": extra,
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(_step_dir(self.base, s), ignore_errors=True)

    # -- read --------------------------------------------------------------
    def committed_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.base):
            full = os.path.join(self.base, name)
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(full, "COMMIT")):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``tree_like``.

        ``shardings`` (optional pytree of NamedSharding, possibly for a
        *different* mesh than the one that saved) re-shards each leaf via
        ``jax.device_put`` — the elastic-rescale path.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.base}")
        d = _step_dir(self.base, step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(d, "shard_00000.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(meta["n_leaves"])]
        _, treedef = jax.tree_util.tree_flatten(tree_like)
        if _treedef_token(treedef) != meta["treedef"]:
            raise ValueError("checkpoint tree structure mismatch")
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
            leaves = [jax.device_put(l, s)
                      for l, s in zip(leaves, sh_leaves)]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, meta["extra"]


def _treedef_token(treedef) -> str:
    return str(treedef)
