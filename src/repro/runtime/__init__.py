from repro.runtime.pipeline import bubble_fraction, gpipe
from repro.runtime.trainer import (SimulatedFailure, StragglerMonitor,
                                   Trainer, TrainerConfig, elastic_restore,
                                   make_train_step)

__all__ = ["bubble_fraction", "gpipe", "SimulatedFailure",
           "StragglerMonitor", "Trainer", "TrainerConfig",
           "elastic_restore", "make_train_step"]
