"""Fault-tolerant distributed training loop.

Assembles the stack: config → TRA sharding plan → jitted train step →
AdamW → checkpoint/restart.  Designed so that every piece of state needed
to survive a node failure lives in exactly two places: the CheckpointStore
(durable) and the DataLoader step counter (restored from the checkpoint's
``extra``); a restart is therefore byte-reproducible (tested).

Fault-tolerance model (1000+ nodes):

* **Checkpoint/restart** — async checkpoints every ``ckpt_every`` steps;
  a crash loses at most ``ckpt_every`` steps of work.  Saves are atomic
  (COMMIT marker), so a failure *during* a save is also safe.
* **Failure injection** — ``train(..., failure_injector=...)`` raises
  :class:`SimulatedFailure` inside the step loop; the loop recovers
  through the same restore path a real restart would take.
* **Straggler mitigation** — :class:`StragglerMonitor` keeps an EMA of
  step wall-time and flags outliers; on a real cluster the runner responds
  by evicting the slow host and re-meshing (the elastic path below).  In
  synchronous SPMD this is the correct lever: one slow chip gates the
  collective, so the fix is topology surgery, not per-op tricks.
* **Elastic re-scale** — checkpoints are topology-free (unsharded leaves),
  so :func:`elastic_restore` can bring a run up on a *different* mesh;
  the TRA planner re-plans placements for the new mesh and the state is
  re-sharded on load.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs.base import ModelConfig
from repro.data import DataConfig, DataLoader
from repro.models import init_params, loss_fn
from repro.models.layers import no_shard
from repro.optim import AdamWConfig, adamw
from repro.optim import schedule as schedules
from repro.sharding import (batch_pspecs, make_sharder, param_pspecs,
                            plan_arch, zero1_pspecs)

# canonical definition lives with the TRA fault model; re-exported here so
# the dense trainer and the TRA trainer recover from the same fault type
from repro.core.faults import SimulatedFailure  # noqa: F401


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    accum_steps: int = 1           # microbatch gradient accumulation
    warmup: int = 10
    zero1: bool = True             # shard optimizer state over data axes
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class StragglerMonitor:
    """EMA step-time tracker; flags steps slower than ``threshold×`` EMA."""

    def __init__(self, threshold: float = 2.0, decay: float = 0.9):
        self.threshold = threshold
        self.decay = decay
        self.ema: Optional[float] = None
        self.flagged: list = []

    def observe(self, step: int, dt: float) -> bool:
        straggler = self.ema is not None and dt > self.threshold * self.ema
        self.ema = dt if self.ema is None else \
            self.decay * self.ema + (1 - self.decay) * dt
        if straggler:
            self.flagged.append((step, dt))
        return straggler


def make_train_step(cfg: ModelConfig, acfg: AdamWConfig,
                    schedule: Callable, sharder) -> Callable:
    """Pure (opt_state, batch) -> (opt_state, metrics) step.

    With ``accum > 1`` the batch carries a leading microbatch dim and
    gradients accumulate in f32 before the (single) reduction — which is
    where bf16-with-error-feedback compression applies.
    """
    def cast_params(master):
        dt = jnp.dtype(cfg.dtype)

        def one(path, leaf):
            last = str(getattr(path[-1], "key", ""))
            keep_f32 = last in ("scale", "a_log", "dt_bias", "d_skip",
                                "router")
            return leaf if keep_f32 else leaf.astype(dt)

        return jax.tree_util.tree_map_with_path(one, master)

    def step_fn(opt_state, batch):
        params = cast_params(opt_state["master"])

        def lf(p, b):
            return loss_fn(cfg, p, b, sharder)

        if batch.get("tokens", batch.get("embeds")).ndim == \
                2 + (0 if cfg.input_mode == "tokens" else 1):
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
        else:
            # leading microbatch dim: scan-accumulate f32 grads
            def mb(carry, b):
                (loss, metrics), grads = jax.value_and_grad(
                    lf, has_aux=True)(params, b)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), carry, grads)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, ms) = jax.lax.scan(mb, zeros, batch)
            n = losses.shape[0]
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        scale = schedule(opt_state["step"])
        new_state, _, opt_metrics = adamw.apply(opt_state, grads, acfg,
                                                lr_scale=scale)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return step_fn


class Trainer:
    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig,
                 tcfg: TrainerConfig, mesh=None, shape=None):
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.store = CheckpointStore(tcfg.ckpt_dir, keep=tcfg.keep)
        self.monitor = StragglerMonitor()

        if mesh is not None:
            from repro.configs.base import ShapeSpec
            shape = shape or ShapeSpec("train", data_cfg.seq_len,
                                       data_cfg.global_batch, "train")
            self.plan = plan_arch(cfg, shape, mesh)
            self.sharder = make_sharder(mesh, self.plan.act_axis_map)
        else:
            self.plan = None
            self.sharder = no_shard

        sched = lambda s: schedules.linear_warmup_cosine(
            s, warmup=tcfg.warmup, total=tcfg.steps)
        self._step_fn = make_train_step(cfg, tcfg.adamw, sched,
                                        self.sharder)
        self._jit_step = None
        self.loader = DataLoader(data_cfg)
        self.opt_state = None
        self.history: list = []

    # -- state -------------------------------------------------------------
    def _shardings_for(self, opt_state_shapes):
        if self.mesh is None:
            return None
        from jax.sharding import NamedSharding
        pmap = self.plan.param_axis_map
        spec_fn = zero1_pspecs if self.tcfg.zero1 else param_pspecs
        master = spec_fn(self.mesh, pmap, opt_state_shapes["master"])
        return {
            "step": NamedSharding(
                self.mesh, jax.sharding.PartitionSpec()),
            "master": jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), master),
            "m": jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), master),
            "v": jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), master),
        }

    def init_state(self) -> None:
        params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        self.opt_state = adamw.init(params)
        if self.mesh is not None:
            sh = self._shardings_for(self.opt_state)
            self.opt_state = jax.tree.map(jax.device_put, self.opt_state,
                                          sh)

    def restore(self) -> bool:
        step = self.store.latest_step()
        if step is None:
            return False
        if self.opt_state is None:
            params = jax.eval_shape(
                lambda: init_params(self.cfg,
                                    jax.random.PRNGKey(self.tcfg.seed)))
            like = {"step": jax.ShapeDtypeStruct((), jnp.int32),
                    "master": jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(s.shape,
                                                       jnp.float32), params),
                    "m": jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(s.shape,
                                                       jnp.float32), params),
                    "v": jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(s.shape,
                                                       jnp.float32), params)}
        else:
            like = self.opt_state
        sh = self._shardings_for(like) if self.mesh is not None else None
        self.opt_state, extra = self.store.restore(like, step, sh)
        self.loader.load_state_dict({"step": extra["data_step"]})
        return True

    def init_or_restore(self) -> None:
        if not self.restore():
            self.init_state()

    # -- loop --------------------------------------------------------------
    def _compiled_step(self):
        if self._jit_step is None:
            if self.mesh is not None:
                donate = (0,)
                self._jit_step = jax.jit(self._step_fn,
                                         donate_argnums=donate)
            else:
                self._jit_step = jax.jit(self._step_fn,
                                         donate_argnums=(0,))
        return self._jit_step

    def save(self) -> None:
        self.store.wait()
        step = int(jax.device_get(self.opt_state["step"]))
        self.store.save_async(step, self.opt_state,
                              extra={"data_step": self.loader.step})

    def train(self, steps: Optional[int] = None,
              failure_injector: Optional[Callable[[int], None]] = None
              ) -> list:
        steps = steps or self.tcfg.steps
        if self.opt_state is None:
            self.init_or_restore()
        fn = self._compiled_step()
        done = int(jax.device_get(self.opt_state["step"]))
        while done < steps:
            batch_np = next(self.loader)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            try:
                if failure_injector is not None:
                    failure_injector(done)
                self.opt_state, metrics = fn(self.opt_state, batch)
                done = int(jax.device_get(self.opt_state["step"]))
            except SimulatedFailure:
                # node loss: recover exactly as a fresh process would
                self.store.wait()
                self.opt_state = None
                self._jit_step = None
                self.init_or_restore()
                fn = self._compiled_step()
                done = int(jax.device_get(self.opt_state["step"]))
                continue
            dt = time.perf_counter() - t0
            self.monitor.observe(done, dt)
            rec = {k: float(jax.device_get(v)) for k, v in metrics.items()}
            rec["step"] = done
            rec["wall"] = dt
            self.history.append(rec)
            if done % self.tcfg.ckpt_every == 0:
                self.save()
        self.store.wait()
        return self.history


def elastic_restore(store: CheckpointStore, cfg: ModelConfig,
                    new_mesh, shape, tcfg: TrainerConfig):
    """Bring a checkpoint up on a different mesh (elastic re-scale)."""
    params = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(tcfg.seed)))
    f32 = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
    like = {"step": jax.ShapeDtypeStruct((), jnp.int32),
            "master": f32(params), "m": f32(params), "v": f32(params)}
    plan = plan_arch(cfg, shape, new_mesh)
    from jax.sharding import NamedSharding
    spec_fn = zero1_pspecs if tcfg.zero1 else param_pspecs
    master = spec_fn(new_mesh, plan.param_axis_map, like["master"])
    sh = {"step": NamedSharding(new_mesh, jax.sharding.PartitionSpec()),
          "master": jax.tree.map(lambda s: NamedSharding(new_mesh, s),
                                 master),
          "m": jax.tree.map(lambda s: NamedSharding(new_mesh, s), master),
          "v": jax.tree.map(lambda s: NamedSharding(new_mesh, s), master)}
    state, extra = store.restore(like, None, sh)
    return state, extra, plan
