"""GPipe-style pipeline parallelism over a mesh axis.

The paper (§6) notes pipelined model parallelism is the database
community's *inter-operation parallelism*; in TRA terms each stage is a
site-partitioned relation of layer weights (``PART_{stage}``) and the
activation handoff is a ``SHUF`` on the stage key dim.  Here the handoff
is the TPU-idiomatic ``jax.lax.ppermute`` ring step inside ``shard_map``.

Schedule: plain GPipe fill-drain over ``M`` microbatches and ``S`` stages
(M + S − 1 ticks).  Bubble fraction = (S−1)/(M+S−1); callers pick M ≫ S.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                      # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map

# pvary marks an array as varying over manual axes (new shard_map type
# system); older jax has no notion of it and needs no marker.
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def gpipe(stage_fn: Callable, mesh: Mesh, stage_axis: str):
    """Build a pipelined ``(stacked_params, microbatches) -> outputs`` fn.

    ``stage_fn(params_slice, x) -> y`` maps one stage over one microbatch
    (x and y must share shape/dtype).  ``stacked_params`` leaves have a
    leading stage dim (== mesh.shape[stage_axis]); ``microbatches`` is
    ``(M, B, ...)``.  Returns outputs ``(M, B, ...)`` after all stages.
    """
    S = mesh.shape[stage_axis]

    def local(params, xs):
        # inside shard_map: params leaves (1, ...) — this stage's slice
        params = jax.tree.map(lambda l: l[0], params)
        M = xs.shape[0]
        stage = jax.lax.axis_index(stage_axis)
        ticks = M + S - 1
        buf = jnp.zeros_like(xs[0])                  # incoming activation
        outs = jnp.zeros_like(xs)
        # carries become stage-varying after the first ppermute
        buf = _pvary(buf, (stage_axis,))
        outs = _pvary(outs, (stage_axis,))

        def tick(t, carry):
            buf, outs = carry
            mb = jnp.clip(t, 0, M - 1)
            inp = jnp.where(stage == 0,
                            xs[mb].astype(buf.dtype), buf)
            y = stage_fn(params, inp)
            # pass activations down the ring (last stage's send unused)
            nxt = jax.lax.ppermute(
                y, stage_axis, [(i, (i + 1) % S) for i in range(S)])
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            take = (stage == S - 1) & (t >= S - 1)
            upd = jnp.where(take, y, outs[out_idx])
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, upd.astype(outs.dtype), out_idx, 0)
            return nxt, outs

        buf, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs: sum the one-hot stack
        outs = jnp.where(stage == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, stage_axis)

    def run(stacked_params, microbatches):
        pspec = jax.tree.map(lambda _: P(stage_axis), stacked_params)
        return _shard_map(
            local, mesh=mesh,
            in_specs=(pspec, P()), out_specs=P(),
        )(stacked_params, microbatches)

    return run


def bubble_fraction(n_microbatches: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
